"""Quantized KV page pools (``kv_dtype`` in {bf16, int8, fp8_e4m3}).

The contracts pinned here are the quantized-pool acceptance bars:

* quantize/dequantize round-trips within the per-line absmax/qmax error
  bound, with scales shaped per cache line (per (page, line, kv_head) for
  GQA pools, per (page, line) for MLA latent pools);
* all four paged-attention Pallas kernels (GQA/MLA x decode/verify), in
  both the single- and double-buffered pipelines, match their
  identically-quantized jnp oracles to kernel tolerance, so the
  engine's backend token-identity checks hold;
* engine-level token equality between the pallas and jnp backends at
  int8 for a GQA arch and an MLA arch, plain decode and speculative
  verify;
* the roofline ledger prices the shrunk line: ``kv_line_bytes`` drops
  >= 1.8x at int8 on the full-size configs, and the VMEM closed form
  still matches the kernel-grid walk;
* lifecycle: copy-on-write isolates quantized pages (scales included),
  preemption swap round-trips them byte-exactly, disaggregated KV-page
  migration stays byte-identical through the cut, and the scale leaves
  ride the SAME single-DMA SwapSnapshot as the values;
* capacity: ``capacity_report`` recomputes page_bytes from the quantized
  line, so the capacity-implied max batch grows >= 1.8x at int8.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.kernels import paged_attention as pa
from repro.kernels import quantize as kvq
from repro.models import init_params, prefill
from repro.models.common import BlockDef
from repro.serve import (Cluster, Engine, EngineConfig, GenerateConfig,
                         PagedKVCache, RoleConfig, Router, SpecConfig,
                         make_engine)
from repro.serve.crosscheck import capacity_report, crosscheck_vmem
from repro.serve.scheduler import kv_line_bytes

QDTYPES = ["int8", "fp8_e4m3"]


def _supported(kv_dtype):
    try:
        kvq.validate_kv_dtype(kv_dtype)
    except ValueError:
        pytest.skip(f"{kv_dtype} not supported by this jax build")


@functools.lru_cache(maxsize=None)
def _gqa():
    cfg = smoke(get_config("qwen3-0.6b"))
    return cfg, init_params(cfg, jax.random.key(0))


@functools.lru_cache(maxsize=None)
def _mla():
    # MoE-free MLA smoke config (same rationale as test_router_cluster:
    # expert-capacity cutoffs carry a batch-composition discontinuity
    # that would break exact byte-identity comparisons)
    cfg = smoke(get_config("deepseek-v2-236b"))
    cfg = dataclasses.replace(
        cfg, name="mla-dense-smoke", mla_absorb=True, n_experts=0,
        moe_top_k=0, moe_d_ff=0, n_shared_experts=0, moe_first_dense=0,
        n_layers=2, block_pattern=(BlockDef("mla", "dense"),))
    return cfg, init_params(cfg, jax.random.key(0))


def _prompt(cfg, seed, length):
    return np.asarray(jax.random.randint(jax.random.key(seed), (length,), 0,
                                         cfg.vocab_size), np.int32)


def _ragged_tables(rng, B, n_blocks, page, num_pages):
    bt = np.zeros((B, n_blocks), np.int32)
    pos = np.zeros((B,), np.int32)
    free = list(range(1, num_pages))
    for b in range(B):
        live = rng.randint(1, n_blocks + 1)
        for j in range(live):
            bt[b, j] = free.pop()
        pos[b] = rng.randint(0, live * page)
    return jnp.asarray(bt), jnp.asarray(pos)


# -- the quantizer ---------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", QDTYPES)
def test_quantize_roundtrip_error_bound(kv_dtype):
    """Symmetric absmax quantization along the last axis: stored values
    take the storage dtype, scales are float32 per leading index, and the
    dequantized round-trip sits within the per-line step size."""
    _supported(kv_dtype)
    x = np.asarray(jax.random.normal(jax.random.key(0), (3, 4, 2, 16)),
                   np.float32) * 5.0
    q, s = kvq.quantize(jnp.asarray(x), kv_dtype, -1)
    assert q.dtype == kvq.store_dtype(kv_dtype, "bfloat16")
    assert s.dtype == jnp.float32 and s.shape == x.shape[:-1]
    dq = np.asarray(kvq.dequantize(q, s), np.float32)
    absmax = np.abs(x).max(axis=-1, keepdims=True)
    if kv_dtype == "int8":
        bound = absmax / 127.0 * 0.5 + 1e-6      # half an int8 step
    else:
        # e4m3: 3 mantissa bits -> half-ulp relative error 2^-4, plus a
        # floor for values scaled into the subnormal range
        bound = np.abs(x) * 2.0 ** -4 + absmax / 448.0
    assert np.all(np.abs(x - dq) < bound)


def test_quantized_pool_defs_and_store_dtype():
    """The pool ParamDefs switch to the storage dtype and grow per-line
    float32 scale leaves exactly when the config asks for quantization."""
    cfg, _ = _gqa()
    qcfg = dataclasses.replace(cfg, kv_dtype="int8")
    assert not kvq.is_quantized(cfg.kv_dtype)
    assert kvq.is_quantized(qcfg.kv_dtype)
    assert kvq.store_itemsize(qcfg.kv_dtype, qcfg.dtype) == 1
    kv = PagedKVCache(qcfg, num_slots=2, page_size=4, max_len=16)
    blk = kv.pools[0][next(iter(kv.pools[0]))]
    assert blk["k"].dtype == jnp.int8 and blk["v"].dtype == jnp.int8
    assert blk["k_scale"].dtype == jnp.float32
    assert blk["k_scale"].shape == blk["k"].shape[:-1]


# -- kernel oracle identity ---------------------------------------------------

@pytest.mark.parametrize("pipeline", ["off", "double"])
@pytest.mark.parametrize("kv_dtype", QDTYPES)
def test_gqa_decode_kernel_matches_quantized_oracle(kv_dtype, pipeline):
    _supported(kv_dtype)
    B, KV, G, hd, page, nb = 3, 2, 2, 16, 4, 5
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(21), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kq, k_s = kvq.quantize(jax.random.normal(ks[1], (P, page, KV, hd)),
                           kv_dtype, -1)
    vq, v_s = kvq.quantize(jax.random.normal(ks[2], (P, page, KV, hd)),
                           kv_dtype, -1)
    bt, pos = _ragged_tables(np.random.RandomState(7), B, nb, page, P)
    ref = pa.paged_attention_reference(q, kq, vq, bt, pos, scale=hd ** -0.5,
                                       k_scale=k_s, v_scale=v_s)
    out = pa.paged_attention(q, kq, vq, bt, pos, scale=hd ** -0.5,
                             k_scale=k_s, v_scale=v_s, interpret=True,
                             pipeline=pipeline)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("pipeline", ["off", "double"])
def test_gqa_verify_kernel_matches_quantized_oracle(pipeline):
    B, T, KV, G, hd, page, nb = 2, 3, 2, 2, 16, 4, 4
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(22), 3)
    q = jax.random.normal(ks[0], (B, T, KV, G, hd))
    kq, k_s = kvq.quantize(jax.random.normal(ks[1], (P, page, KV, hd)),
                           "int8", -1)
    vq, v_s = kvq.quantize(jax.random.normal(ks[2], (P, page, KV, hd)),
                           "int8", -1)
    bt, pos = _ragged_tables(np.random.RandomState(9), B, nb, page, P)
    pos = jnp.minimum(pos, nb * page - T)
    ref = pa.paged_attention_verify_reference(
        q, kq, vq, bt, pos, scale=hd ** -0.5, k_scale=k_s, v_scale=v_s)
    out = pa.paged_attention_verify(
        q, kq, vq, bt, pos, scale=hd ** -0.5, k_scale=k_s, v_scale=v_s,
        interpret=True, pipeline=pipeline)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("pipeline", ["off", "double"])
@pytest.mark.parametrize("kv_dtype", QDTYPES)
def test_mla_decode_kernel_matches_quantized_oracle(kv_dtype, pipeline):
    _supported(kv_dtype)
    B, H, r, dr, page, nb = 3, 4, 32, 8, 4, 4
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(23), 4)
    ql = jax.random.normal(ks[0], (B, H, r))
    qr = jax.random.normal(ks[1], (B, H, dr))
    cq, c_s = kvq.quantize(jax.random.normal(ks[2], (P, page, r)),
                           kv_dtype, -1)
    rq, r_s = kvq.quantize(jax.random.normal(ks[3], (P, page, dr)),
                           kv_dtype, -1)
    bt, pos = _ragged_tables(np.random.RandomState(11), B, nb, page, P)
    ref = pa.mla_paged_attention_reference(
        ql, qr, cq, rq, bt, pos, scale=(r + dr) ** -0.5,
        c_scale=c_s, r_scale=r_s)
    out = pa.mla_paged_attention(
        ql, qr, cq, rq, bt, pos, scale=(r + dr) ** -0.5,
        c_scale=c_s, r_scale=r_s, interpret=True, pipeline=pipeline)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("pipeline", ["off", "double"])
def test_mla_verify_kernel_matches_quantized_oracle(pipeline):
    B, T, H, r, dr, page, nb = 2, 3, 4, 32, 8, 4, 4
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(24), 4)
    ql = jax.random.normal(ks[0], (B, T, H, r))
    qr = jax.random.normal(ks[1], (B, T, H, dr))
    cq, c_s = kvq.quantize(jax.random.normal(ks[2], (P, page, r)),
                           "int8", -1)
    rq, r_s = kvq.quantize(jax.random.normal(ks[3], (P, page, dr)),
                           "int8", -1)
    bt, pos = _ragged_tables(np.random.RandomState(13), B, nb, page, P)
    pos = jnp.minimum(pos, nb * page - T)
    ref = pa.mla_paged_attention_verify_reference(
        ql, qr, cq, rq, bt, pos, scale=(r + dr) ** -0.5,
        c_scale=c_s, r_scale=r_s)
    out = pa.mla_paged_attention_verify(
        ql, qr, cq, rq, bt, pos, scale=(r + dr) ** -0.5,
        c_scale=c_s, r_scale=r_s, interpret=True, pipeline=pipeline)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


# -- engine byte-identity --------------------------------------------------

def _engine_tokens(cfg, params, backend, kv_dtype, prompts, gen,
                   pipeline="off"):
    eng = Engine(cfg, params, EngineConfig(
        num_slots=2, page_size=4, max_len=32, kernel_backend=backend,
        kv_dtype=kv_dtype, pipeline=pipeline))
    reqs = [eng.submit(p, gen) for p in prompts]
    eng.run()
    return [list(r.generated) for r in reqs]


@pytest.mark.parametrize("cfg_fn", [_gqa, _mla])
def test_engine_pallas_matches_quantized_jnp_oracle(cfg_fn):
    """The end-to-end bar: at int8 the pallas-kernel engine and the jnp
    oracle engine quantize identically, so their greedy tokens match."""
    cfg, params = cfg_fn()
    prompts = [_prompt(cfg, 40 + i, 5 + i) for i in range(2)]
    gen = GenerateConfig(max_new_tokens=6)
    a = _engine_tokens(cfg, params, "pallas", "int8", prompts, gen)
    b = _engine_tokens(cfg, params, "jnp", "int8", prompts, gen)
    assert a == b
    assert all(len(t) == 6 for t in a)


def test_engine_double_pipeline_quantized_byte_identity():
    cfg, params = _gqa()
    prompts = [_prompt(cfg, 44 + i, 5 + i) for i in range(2)]
    gen = GenerateConfig(max_new_tokens=6)
    a = _engine_tokens(cfg, params, "pallas", "int8", prompts, gen,
                       pipeline="double")
    b = _engine_tokens(cfg, params, "jnp", "int8", prompts, gen)
    assert a == b


def test_spec_verify_quantized_byte_identity():
    """Speculative verify walks the same quantized pages: pallas and jnp
    backends must agree token for token through draft/verify rounds."""
    cfg, params = _gqa()
    motif = _prompt(cfg, 47, 4)
    prompt = np.tile(motif, 4)
    gen = GenerateConfig(max_new_tokens=8)
    outs = {}
    for be in ("pallas", "jnp"):
        eng = make_engine(cfg, params,
                          EngineConfig(num_slots=2, page_size=4, max_len=48,
                                       kernel_backend=be, kv_dtype="int8"),
                          SpecConfig(k=3, proposer="ngram"))
        req = eng.submit(prompt, gen)
        eng.run()
        outs[be] = list(req.generated)
    assert outs["pallas"] == outs["jnp"]
    assert len(outs["pallas"]) == 8


def test_engine_config_kv_dtype_overrides_model_config():
    cfg, params = _gqa()
    eng = Engine(cfg, params, EngineConfig(num_slots=2, page_size=4,
                                           max_len=16, kv_dtype="int8"))
    assert eng.cfg.kv_dtype == "int8"
    with pytest.raises(ValueError, match="kv_dtype"):
        Engine(cfg, params, EngineConfig(num_slots=2, page_size=4,
                                         max_len=16, kv_dtype="int3"))


# -- ledger pricing --------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v2-236b"])
def test_kv_line_bytes_shrink_at_int8(arch):
    """The acceptance bar: the all-layer decode KV line drops >= 1.8x at
    int8 on the FULL-SIZE configs (values at 1 byte + per-line f32
    scales vs bf16 values) — the direct AI multiplier decode inherits."""
    cfg = get_config(arch)
    base = kv_line_bytes(cfg)
    q8 = kv_line_bytes(dataclasses.replace(cfg, kv_dtype="int8"))
    assert q8 < base
    assert base / q8 >= 1.8, (base, q8)


def test_vmem_crosscheck_quantized():
    """The closed-form VMEM pricing and the independent kernel-grid walk
    must stay in lockstep at the quantized line size."""
    cfg, params = _gqa()
    eng = Engine(cfg, params, EngineConfig(num_slots=2, page_size=4,
                                           max_len=32,
                                           kernel_backend="pallas",
                                           kv_dtype="int8"))
    gen = GenerateConfig(max_new_tokens=6)
    done = [eng.submit(_prompt(cfg, 50 + i, 5), gen) for i in range(2)]
    eng.run()
    cv = crosscheck_vmem(eng, requests=done)
    assert abs(cv["vmem_ratio"] - 1.0) <= 0.02, cv


# -- capacity --------------------------------------------------------------

def test_capacity_max_batch_grows_at_int8():
    """Satellite bar: capacity_report recomputes page_bytes from the
    quantized line, so the HBM-implied max batch grows >= 1.8x."""
    cfg, params = _gqa()
    caps = {}
    gen = GenerateConfig(max_new_tokens=4)
    for kvd in (None, "int8"):
        eng = Engine(cfg, params, EngineConfig(num_slots=2, page_size=4,
                                               max_len=16, kv_dtype=kvd))
        eng.submit(_prompt(cfg, 60, 6), gen)
        eng.run()
        caps[kvd] = capacity_report(eng)
    ratio = caps[None]["page_bytes"] / caps["int8"]["page_bytes"]
    assert ratio >= 1.8, caps
    assert (caps["int8"]["capacity_max_batch"]
            >= 1.8 * caps[None]["capacity_max_batch"]), caps


# -- lifecycle -------------------------------------------------------------

def _prefilled_q(cfg_fn, kv_dtype, S):
    cfg, params = cfg_fn()
    cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    prompt = jax.random.randint(jax.random.key(1), (1, S), 0,
                                cfg.vocab_size)
    _, states = prefill(params, cfg, prompt)
    return cfg, prompt, states


def test_cow_isolates_quantized_pages():
    """Copy-on-write must copy the quantized values AND their scales: the
    writer's copy carries identical dequantized bytes, the sibling's
    view never moves."""
    S = 8
    cfg, prompt, states = _prefilled_q(_gqa, "int8", S)
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16,
                      prefix_cache=True)
    toks = np.asarray(prompt[0])
    a = kv.alloc(S, budget=16, tokens=toks)
    kv.write_prefill_states(a, states, S)
    b = kv.alloc(S, budget=16, tokens=toks)
    np.testing.assert_array_equal(kv.block_tables[a][:2],
                                  kv.block_tables[b][:2])
    before_a = np.asarray(jax.tree.leaves(kv.dense_view(a)[0])[0]).copy()
    assert kv.ensure_writable(b, S - 1, S)       # CoW in the shared page
    assert kv.pool.stats.cow_copies == 1
    assert kv.block_tables[a][1] != kv.block_tables[b][1]
    after_a = np.asarray(jax.tree.leaves(kv.dense_view(a)[0])[0])
    np.testing.assert_array_equal(before_a, after_a)
    va = jax.tree.leaves(kv.dense_view(a)[0])[0]
    vb = jax.tree.leaves(kv.dense_view(b)[0])[0]
    np.testing.assert_array_equal(np.asarray(va[:, :, :S]),
                                  np.asarray(vb[:, :, :S]))
    kv.pool.check(kv.table_refs())


@pytest.mark.parametrize("cfg_fn", [_gqa, _mla])
def test_swap_roundtrip_quantized_single_dma(cfg_fn):
    """swap_out -> swap_in round-trips quantized pages byte-exactly, and
    the scale leaves pack into the SAME single host DMA as the values
    (transfers saved = all leaves but one)."""
    S = 6
    cfg, prompt, states = _prefilled_q(cfg_fn, "int8", S)
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=12)
    s = kv.alloc(S, budget=12)
    kv.write_prefill_states(s, states, S)
    before = [np.asarray(x) for x in jax.tree.leaves(kv.dense_view(s))]
    n_leaves = sum(len(jax.tree.leaves(seg)) for seg in kv.pools)
    n_scales = sum(1 for seg in kv.pools for blk in seg.values()
                   for name in blk if name.endswith("_scale"))
    assert n_scales > 0
    snap = kv.swap_out(s)
    assert kv.pool.stats.swap_dmas == 1
    assert kv.pool.stats.swap_transfers_saved == n_leaves - 1
    blocker = kv.alloc(4, slot=s)                # force a different slot
    s2 = kv.swap_in(snap)
    assert s2 is not None and s2 != s
    after = [np.asarray(x) for x in jax.tree.leaves(kv.dense_view(s2))]
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, y)
    kv.free(blocker)
    kv.pool.check(kv.table_refs())


def test_preemption_swap_byte_identity_quantized():
    """An undersized pool at int8: preempted requests swap their
    quantized pages (scales riding along) to host and resume
    byte-identically to the fully backed quantized run."""
    cfg, params = _gqa()
    prompts = [_prompt(cfg, 70 + i, 6) for i in range(3)]
    gen = GenerateConfig(max_new_tokens=6)

    def run(num_pages):
        eng = Engine(cfg, params, EngineConfig(
            num_slots=2, page_size=4, max_len=16, kv_dtype="int8",
            num_pages=num_pages, preempt_mode="swap"))
        reqs = [eng.submit(p, gen) for p in prompts]
        eng.run()
        return eng, [list(r.generated) for r in reqs]

    _, base = run(None)
    eng, tight = run(6)
    assert tight == base
    assert eng._sched.preempt_count > 0, "the pool never ran dry"
    eng._kv.pool.check(eng._kv.table_refs())


@pytest.mark.parametrize("cfg_fn,seed", [(_gqa, 500), (_mla, 600)])
def test_migration_quantized_byte_identity(cfg_fn, seed):
    """Disaggregated prefill/decode at int8: the packed-snapshot handoff
    moves quantized pages + scales over the wire and the decode replica
    continues byte-identically to a single quantized engine."""
    cfg, params = cfg_fn()
    cfg = dataclasses.replace(cfg, kv_dtype="int8")
    ecfg = EngineConfig(num_slots=2, page_size=4, max_len=32)
    prompts = [_prompt(cfg, seed + i, 5 + i) for i in range(3)]
    gen = GenerateConfig(max_new_tokens=6)
    single = Engine(cfg, params, ecfg)
    base = [single.submit(p, gen) for p in prompts]
    single.run()
    base = [list(r.generated) for r in base]
    cluster = Cluster(cfg, params, ecfg, mesh_shape=(2, 1),
                      roles=RoleConfig.disaggregated(1, 1))
    router = Router(cluster)
    reqs = [router.submit(p, gen) for p in prompts]
    router.run()
    assert [list(r.generated) for r in reqs] == base
    assert router.migrations >= len(prompts)
    assert router.migration_bytes > 0
