"""Serve-stack observability: the tracing/metrics/attainment contract.

The whole package rides on three promises:

* **observation-only** — token streams are byte-identical with telemetry
  on or off, across the single engine, the speculative engine and the
  disaggregated router (the same contract the roofline ledger obeys);
* **loadable** — an exported trace passes ``validate_trace``: well-formed
  events, per-track call-stack span nesting, every used track named,
  balanced async request pairs, paired migration flow arrows;
* **honest projection** — the registry exposes exactly the accounting
  the stack already keeps (ledger totals, pool stats, latency traces,
  windowed roofline attainment with the binding roof NAMED), and
  harvesting twice never double-counts.
"""

import functools
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.obs import Telemetry, clock
from repro.obs.metrics import Counter, Registry, harvest_serve
from repro.obs.trace import (ENGINE_TID, LIFECYCLE_TID, Tracer,
                             validate_trace)
from repro.serve import (Cluster, Engine, EngineConfig, GenerateConfig,
                         RoleConfig, Router, SpecConfig, SpecEngine)


@functools.lru_cache(maxsize=None)
def _model():
    cfg = smoke(get_config("qwen3-0.6b"))
    return cfg, init_params(cfg, jax.random.key(0))


def _prompts(cfg, n=3, seed=700, repetitive=False):
    out = []
    for i in range(n):
        if repetitive:
            motif = np.asarray(jax.random.randint(
                jax.random.key(seed + i), (3,), 0, cfg.vocab_size))
            out.append(np.tile(motif, 4).astype(np.int32))
        else:
            out.append(np.asarray(jax.random.randint(
                jax.random.key(seed + i), (5 + i,), 0, cfg.vocab_size),
                np.int32))
    return out


def _ecfg(**kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_len", 32)
    return EngineConfig(**kw)


def _run_engine(telemetry, spec=False):
    cfg, params = _model()
    ecfg = _ecfg(telemetry=telemetry, telemetry_window=2)
    if spec:
        eng = SpecEngine(cfg, params, ecfg,
                         SpecConfig(k=3, proposer="ngram"))
    else:
        eng = Engine(cfg, params, ecfg)
    gen = GenerateConfig(max_new_tokens=6)
    reqs = [eng.submit(p, gen)
            for p in _prompts(cfg, repetitive=spec)]
    eng.run()
    return eng, [list(r.generated) for r in reqs]


# -- the clock -------------------------------------------------------------

def test_clock_monotone_nondecreasing():
    stamps = [clock.now() for _ in range(100)]
    assert all(b >= a for a, b in zip(stamps, stamps[1:]))


# -- tracer + validator units ---------------------------------------------

def _toy_tracer():
    tr = Tracer(epoch=0.0)
    tr.process(0, "engine")
    tr.thread(0, ENGINE_TID, "steps")
    tr.thread(0, LIFECYCLE_TID, "lifecycle")
    return tr


def test_tracer_roundtrip_valid(tmp_path):
    tr = _toy_tracer()
    tr.span("outer", 0, ENGINE_TID, 1e-3, 5e-3)
    tr.span("inner", 0, ENGINE_TID, 2e-3, 3e-3)   # nests: fine
    tr.instant("submit", 0, LIFECYCLE_TID, 1.5e-3, request=0)
    tr.counter("pool_pages", 0, 2e-3, {"in_use": 3})
    tr.async_begin("request", 0, LIFECYCLE_TID, 0, 1e-3)
    tr.async_end("request", 0, LIFECYCLE_TID, 0, 5e-3)
    tr.flow_start("migrate", 0, LIFECYCLE_TID, 7, 2e-3)
    tr.flow_finish("migrate", 0, LIFECYCLE_TID, 7, 4e-3)
    path = tmp_path / "t.json"
    doc = tr.export(str(path))
    assert validate_trace(doc) == []
    import json
    assert json.load(open(path)) == doc
    assert doc["displayTimeUnit"] == "ms"


def test_tracer_clamps_pre_epoch_and_backward_spans():
    tr = _toy_tracer()
    tr.span("pre", 0, ENGINE_TID, -1.0, -0.5)     # before the epoch
    tr.span("backward", 0, ENGINE_TID, 9e-3, 8e-3)  # t1 < t0
    doc = tr.export()
    assert validate_trace(doc) == []
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)


def test_validator_rejects_malformed_documents():
    assert validate_trace([]) != []
    assert validate_trace({"traceEvents": []}) != []
    # missing required keys
    doc = {"displayTimeUnit": "ms",
           "traceEvents": [{"ph": "X", "name": "x"}]}
    assert any("missing keys" in e for e in validate_trace(doc))
    # negative duration
    tr = _toy_tracer()
    doc = tr.export()
    doc["traceEvents"].append({"ph": "X", "name": "bad", "pid": 0,
                               "tid": ENGINE_TID, "ts": 1.0, "dur": -2.0})
    assert any("bad dur" in e for e in validate_trace(doc))


def test_validator_rejects_partial_overlap_but_allows_nesting():
    tr = _toy_tracer()
    tr.span("a", 0, ENGINE_TID, 1e-3, 3e-3)
    tr.span("b", 0, ENGINE_TID, 2e-3, 4e-3)       # partial overlap
    errs = validate_trace(tr.export())
    assert any("partially overlaps" in e for e in errs)
    tr2 = _toy_tracer()
    tr2.span("a", 0, ENGINE_TID, 1e-3, 4e-3)
    tr2.span("b", 0, ENGINE_TID, 2e-3, 3e-3)      # proper nesting
    assert validate_trace(tr2.export()) == []


def test_validator_rejects_orphans_and_unnamed_tracks():
    tr = _toy_tracer()
    tr.async_begin("request", 0, LIFECYCLE_TID, 1, 1e-3)   # no end
    tr.flow_start("migrate", 0, LIFECYCLE_TID, 2, 1e-3)    # no finish
    errs = validate_trace(tr.export())
    assert any("orphan id" in e for e in errs)
    assert any("flow id 2: orphan" in e for e in errs)
    tr2 = Tracer(epoch=0.0)                       # no metadata at all
    tr2.instant("submit", 3, 7, 1e-3)
    errs2 = validate_trace(tr2.export())
    assert any("no process_name" in e for e in errs2)
    assert any("no thread_name" in e for e in errs2)


# -- registry units --------------------------------------------------------

def test_counter_set_total_is_monotone_idempotent():
    c = Counter("x_total", "help")
    c.set_total(5.0)
    c.set_total(5.0)
    c.set_total(3.0)                              # re-harvest never rewinds
    assert c.values[()] == 5.0
    with pytest.raises(ValueError):
        c.inc(-1.0)


def test_registry_exposition_format():
    reg = Registry()
    reg.counter("serve_x_total", "things", ("kind",)).inc(2.0, kind="a")
    reg.gauge("serve_g", "a gauge").set(1.5)
    h = reg.histogram("serve_lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = reg.expose()
    assert "# HELP serve_x_total things" in text
    assert "# TYPE serve_x_total counter" in text
    assert 'serve_x_total{kind="a"} 2.0' in text
    assert "# TYPE serve_lat_seconds histogram" in text
    assert 'serve_lat_seconds_bucket{le="0.1"} 1' in text
    assert 'serve_lat_seconds_bucket{le="1.0"} 2' in text     # cumulative
    assert 'serve_lat_seconds_bucket{le="+Inf"} 2' in text
    assert "serve_lat_seconds_count 2" in text
    assert text.endswith("\n")
    # same family, different type: refused
    with pytest.raises(TypeError):
        reg.gauge("serve_x_total")


# -- observation-only: byte identity on/off --------------------------------

def test_engine_byte_identity_telemetry_on_off():
    _, base = _run_engine(telemetry=False)
    eng, traced = _run_engine(telemetry=True)
    assert traced == base
    assert eng.obs is not None
    doc = eng.obs.export_trace()
    assert validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"prefill_chunk", "decode_step", "submit", "place",
            "first_token", "request"} <= names


def test_spec_engine_byte_identity_and_spans():
    _, base = _run_engine(telemetry=False, spec=True)
    eng, traced = _run_engine(telemetry=True, spec=True)
    assert traced == base
    doc = eng.obs.export_trace()
    assert validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"propose", "verify"} <= names


def test_router_byte_identity_and_migration_trace():
    cfg, params = _model()
    prompts = _prompts(cfg)
    gen = GenerateConfig(max_new_tokens=6)

    def run(telemetry):
        ecfg = _ecfg(telemetry=telemetry, telemetry_window=2)
        cluster = Cluster(cfg, params, ecfg, mesh_shape=(2, 1),
                          roles=RoleConfig.disaggregated(1, 1))
        router = Router(cluster)
        reqs = [router.submit(p, gen) for p in prompts]
        router.run()
        return cluster, router, [list(r.generated) for r in reqs]

    _, _, base = run(False)
    cluster, router, traced = run(True)
    assert traced == base
    assert router.migrations >= len(prompts)
    obs = cluster.obs
    assert obs is not None and all(
        eng.obs is obs for eng in cluster.replicas)
    obs.harvest(cluster)
    doc = obs.export_trace()
    assert validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"dispatch", "migrate", "migrate_out", "migrate_in",
            "prefill_chunk", "decode_step"} <= names
    # every migration draws one complete flow arrow between replicas
    flows = [e for e in doc["traceEvents"] if e["ph"] in ("s", "f")]
    starts = {e["id"] for e in flows if e["ph"] == "s"}
    finishes = {e["id"] for e in flows if e["ph"] == "f"}
    assert starts and starts == finishes
    # and the two replicas + the router each trace as their own process
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert {0, 1, 999} <= pids
    snap = obs.snapshot()
    assert "serve_migrations_total" in snap
    assert 'serve_migration_bytes_total{link="dcn"}' in snap


# -- harvest / attainment --------------------------------------------------

def test_harvest_exposes_ledger_pool_and_latency():
    eng, _ = _run_engine(telemetry=True)
    eng.obs.harvest(eng)
    text = eng.obs.snapshot()
    assert "serve_decode_tokens_total" in text
    assert 'serve_flops_total{phase="decode"}' in text
    assert 'serve_level_bytes_total{level="hbm"}' in text
    assert "serve_pool_pages_in_use" in text
    for seg in ("queue_wait", "prefill", "first_decode", "total"):
        assert f'serve_ttft_seconds_bucket{{segment="{seg}"' in text
    assert "serve_itl_seconds_count" in text
    # harvesting again must not double-count anything
    eng.obs.harvest(eng)
    assert eng.obs.snapshot() == text


def test_attainment_windows_name_the_binding_roof():
    eng, _ = _run_engine(telemetry=True)
    eng.obs.harvest(eng)
    windows = eng.obs.attainment.windows
    assert windows, "a 6-token run must close at least one window"
    for w in windows:
        assert w.binding_roof in w.roofs
        assert w.dt_s > 0 and w.tokens > 0
        assert w.flops_per_s > 0
        # attainment is flops over the per-level roof, so the binding
        # (lowest) roof carries the HIGHEST attainment fraction
        assert w.fraction == pytest.approx(
            max(v for v in w.attainment.values()))
        assert w.fraction == pytest.approx(
            w.flops_per_s / w.roofs[w.binding_roof])
    text = eng.obs.snapshot()
    assert "serve_roofline_attainment{level=" in text
    assert "serve_roofline_binding{roof=" in text
    assert "serve_attained_flops_per_s" in text


def test_telemetry_default_off_leaves_no_hooks():
    cfg, params = _model()
    eng = Engine(cfg, params, _ecfg())
    assert eng.obs is None
    gen = GenerateConfig(max_new_tokens=4)
    eng.submit(_prompts(cfg, n=1)[0], gen)
    eng.run()
    assert eng._sched.obs is None


# -- overhead --------------------------------------------------------------

def test_tracing_overhead_within_bar():
    """Traced wall within 1.25x of untraced (min-of-3 each side; smoke
    walls on shared runners are noisy, so the estimator is the standard
    min-latency one and the whole check retries)."""
    _run_engine(telemetry=False)                  # compile warm-up
    _run_engine(telemetry=True)

    def wall(telemetry):
        t0 = time.perf_counter()
        _run_engine(telemetry=telemetry)
        return time.perf_counter() - t0

    for attempt in range(3):
        base = min(wall(False) for _ in range(3))
        traced = min(wall(True) for _ in range(3))
        if traced / base <= 1.25:
            return
    raise AssertionError(
        f"traced wall {traced * 1e3:.1f}ms exceeds 1.25x the untraced "
        f"{base * 1e3:.1f}ms on every attempt")
