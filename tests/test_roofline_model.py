"""Golden-value tests for the three-term roofline math (model.py edge
cases): dominant-term ties, zero-DCN scopes, useful_ratio > 1, and the
bound_class strings."""

import pytest

from repro.core.roofline.hardware import ChipSpec, ScopeSpec
from repro.core.roofline.model import RooflineTerms, make_terms

# a chip with round numbers so every derived value is exact
CHIP = ChipSpec(
    name="toy",
    peak_flops=100.0,
    peak_flops_by_dtype={"bfloat16": 100.0, "float32": 50.0},
    hbm_bw=10.0,
    hbm_bytes=1 << 30,
    ici_bw=5.0,
    ici_links=1,
    dcn_bw=2.0,
    vmem_bytes=1 << 20,
)


def terms(scope_chips=1, interconnect="none", **kw):
    base = dict(flops_dev=50.0, hbm_bytes_dev=10.0, ici_wire_bytes_dev=0.0,
                dcn_wire_bytes_dev=0.0, dtype="bfloat16")
    base.update(kw)
    return make_terms(scope=ScopeSpec("toy", CHIP, scope_chips,
                                      interconnect), **base)


def test_golden_time_terms():
    t = terms()
    assert t.compute_s == pytest.approx(0.5)        # 50 / 100
    assert t.memory_s == pytest.approx(1.0)         # 10 / 10
    assert t.ici_s == 0.0 and t.dcn_s == 0.0
    assert t.t_lower == pytest.approx(1.0)          # max of terms
    assert t.t_upper == pytest.approx(1.5)          # sum of terms
    assert t.arithmetic_intensity == pytest.approx(5.0)       # 50 / 10
    assert t.ridge_intensity == pytest.approx(10.0)           # 100 / 10
    # left of the ridge: P = I * beta = 50 < pi
    assert t.attainable_flops == pytest.approx(50.0)
    assert t.bound_class() == "memory-bound"
    assert t.hardware_fraction == pytest.approx(0.5)


def test_dominant_term_tie_prefers_compute():
    """compute_s == memory_s: the tie breaks to 'compute' (dict order),
    i.e. a balanced kernel sitting exactly on the ridge reports
    compute-bound — the optimistic reading of P = min(pi, I*beta)."""
    t = terms(flops_dev=100.0, hbm_bytes_dev=10.0)
    assert t.compute_s == pytest.approx(t.memory_s) == pytest.approx(1.0)
    assert t.dominant == "compute"
    assert t.bound_class() == "compute-bound"
    assert t.arithmetic_intensity == pytest.approx(t.ridge_intensity)
    assert t.attainable_flops == pytest.approx(100.0)


def test_zero_dcn_scope():
    """dcn_wire_bytes == 0 must give dcn_s == 0.0 exactly (single-pod
    scopes never pay DCN, whatever the chip's dcn_bw says)."""
    t = terms(ici_wire_bytes_dev=100.0, dcn_wire_bytes_dev=0.0)
    assert t.dcn_s == 0.0
    assert t.ici_s == pytest.approx(20.0)           # 100 / 5
    assert t.collective_s == pytest.approx(20.0)
    assert t.bound_class() == "collective-bound(ici)"


def test_dcn_bound_class():
    t = terms(dcn_wire_bytes_dev=100.0)
    assert t.dcn_s == pytest.approx(50.0)           # 100 / 2
    assert t.bound_class() == "collective-bound(dcn)"
    assert t.t_upper == pytest.approx(0.5 + 1.0 + 50.0)


def test_useful_ratio_above_one():
    """HLO can do *less* work than the analytic 6ND convention (MoE
    active-only counting, cost_analysis folding): useful_ratio > 1 and the
    roofline fraction scales with it."""
    t = terms(flops_dev=50.0, model_flops_total=80.0)
    assert t.model_flops_dev == pytest.approx(80.0)
    assert t.useful_ratio == pytest.approx(1.6)
    # useful_s = 80/100 = 0.8; t_lower = memory_s = 1.0
    assert t.roofline_fraction == pytest.approx(0.8)


def test_useful_ratio_none_without_model_flops():
    t = terms()
    assert t.useful_ratio is None
    assert t.roofline_fraction is None
    assert t.model_flops_dev is None


def test_multichip_scope_divides_model_flops():
    t = terms(scope_chips=4, interconnect="ici", model_flops_total=200.0)
    assert t.n_chips == 4
    assert t.model_flops_dev == pytest.approx(50.0)
    assert t.useful_ratio == pytest.approx(1.0)


def test_dtype_selects_peak():
    t = terms(dtype="float32")
    assert t.compute_s == pytest.approx(1.0)        # 50 / 50
    assert t.ridge_intensity == pytest.approx(5.0)  # 50 / 10


def test_zero_flops_zero_bytes_edge():
    """Empty scopes must not divide by zero: AI guards with max(Q, 1)."""
    t = terms(flops_dev=0.0, hbm_bytes_dev=0.0)
    assert t.arithmetic_intensity == 0.0
    assert t.useful_ratio is None                   # flops_dev == 0 guard
    assert t.t_lower == 0.0
