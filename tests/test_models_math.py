"""Numerical-equivalence tests for the model internals: chunked/parallel
forms vs sequential oracles, decode-vs-full-forward consistency, MLA
absorption, MoE degenerate cases."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import xlstm as xlstm_mod
from repro.models import ssm as ssm_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.common import BlockDef, ModelConfig
from repro.parallel.sharding import tree_instantiate


def test_mamba_chunked_matches_naive():
    cfg = smoke(get_config("jamba-v0.1-52b"))
    cfg = dataclasses.replace(cfg, scan_chunk=8)
    p = tree_instantiate(ssm_mod.mamba_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    chunked = ssm_mod.mamba_mixer(p, x, cfg)
    naive = ssm_mod.mamba_mixer_naive(p, x, cfg)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)


def test_mamba_state_continuity():
    """prefill(x[:16]) then mixer(x[16:]) == mixer(x) — state handoff."""
    cfg = dataclasses.replace(smoke(get_config("jamba-v0.1-52b")),
                              scan_chunk=8)
    p = tree_instantiate(ssm_mod.mamba_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    full = ssm_mod.mamba_mixer(p, x, cfg)
    o1, st = ssm_mod.mamba_mixer(p, x[:, :16], cfg, return_state=True)
    o2 = ssm_mod.mamba_mixer(p, x[:, 16:], cfg, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


def test_mlstm_chunkwise_matches_sequential():
    B, H, T, hd = 2, 3, 32, 16
    ks = jax.random.split(jax.random.key(0), 5)
    q = jax.random.normal(ks[0], (B, H, T, hd))
    k = jax.random.normal(ks[1], (B, H, T, hd)) / (hd ** 0.5)
    v = jax.random.normal(ks[2], (B, H, T, hd))
    li = jax.random.normal(ks[3], (B, H, T))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, T)) + 1.0)
    C0 = jnp.zeros((B, H, hd, hd))
    n0 = jnp.zeros((B, H, hd))
    m0 = jnp.zeros((B, H))
    h_chunk, (Cf, nf, mf) = xlstm_mod._mlstm_chunk(q, k, v, li, lf, C0, n0, m0)
    h_naive = xlstm_mod.mlstm_cell_naive(q, k, v, li, lf, C0, n0, m0)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_naive),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_state_carry():
    """Two sequential chunks == one big chunk (state carry correctness)."""
    B, H, T, hd = 1, 2, 16, 8
    ks = jax.random.split(jax.random.key(7), 5)
    q = jax.random.normal(ks[0], (B, H, 2 * T, hd))
    k = jax.random.normal(ks[1], (B, H, 2 * T, hd)) / (hd ** 0.5)
    v = jax.random.normal(ks[2], (B, H, 2 * T, hd))
    li = jax.random.normal(ks[3], (B, H, 2 * T))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, 2 * T)) + 1.0)
    z = jnp.zeros
    big, _ = xlstm_mod._mlstm_chunk(q, k, v, li, lf,
                                    z((B, H, hd, hd)), z((B, H, hd)),
                                    z((B, H)))
    h1, st = xlstm_mod._mlstm_chunk(q[:, :, :T], k[:, :, :T], v[:, :, :T],
                                    li[:, :, :T], lf[:, :, :T],
                                    z((B, H, hd, hd)), z((B, H, hd)),
                                    z((B, H)))
    h2, _ = xlstm_mod._mlstm_chunk(q[:, :, T:], k[:, :, T:], v[:, :, T:],
                                   li[:, :, T:], lf[:, :, T:], *st)
    got = jnp.concatenate([h1, h2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(big),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_matches_naive():
    cfg = smoke(get_config("deepseek-v2-236b"))
    p = tree_instantiate(mla_mod.mla_defs(cfg), jax.random.key(0))
    B, S = 2, 12
    cache = tree_instantiate(mla_mod.mla_cache_defs(cfg, B, 16),
                             jax.random.key(1))
    # warm the cache with a few junk latents
    cache = {k: v.at[:, :4].set(jax.random.normal(jax.random.key(2),
                                                  v[:, :4].shape, v.dtype))
             for k, v in cache.items()}
    x = jax.random.normal(jax.random.key(3), (B, 1, cfg.d_model))
    pos = jnp.int32(4)
    cfg_n = dataclasses.replace(cfg, mla_absorb=False)
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    o_n, _ = mla_mod.mla_decode(p, x, cache, pos, cfg_n)
    o_a, _ = mla_mod.mla_decode(p, x, cache, pos, cfg_a)
    np.testing.assert_allclose(np.asarray(o_n), np.asarray(o_a),
                               rtol=2e-4, atol=2e-4)


def test_moe_single_expert_equals_dense():
    """E=1, top-1, ample capacity: MoE must equal the dense GLU."""
    cfg = dataclasses.replace(
        smoke(get_config("kimi-k2-1t-a32b")),
        n_experts=1, moe_top_k=1, n_shared_experts=0, capacity_factor=2.0)
    p = tree_instantiate(moe_mod.moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    out, aux = moe_mod.moe_ffn(p, x, cfg)
    from repro.models.layers import activate
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"][0])
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"][0])
    expect = jnp.einsum("bsf,fd->bsd", activate(h, g, cfg.act), p["w_down"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """Tiny capacity factor: outputs shrink but stay finite (GShard drop)."""
    cfg = dataclasses.replace(
        smoke(get_config("deepseek-v2-236b")),
        n_shared_experts=0, capacity_factor=0.25)
    p = tree_instantiate(moe_mod.moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    out, aux = moe_mod.moe_ffn(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0


def test_attention_chunked_matches_direct():
    from repro.models import attention as attn
    cfg = dataclasses.replace(smoke(get_config("qwen3-0.6b")), attn_chunk=8)
    p = tree_instantiate(attn.attn_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    chunked = attn.multihead_attention(p, x, cfg)
    cfg_d = dataclasses.replace(cfg, attn_chunk=4096)
    direct = attn.multihead_attention(p, x, cfg_d)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "xlstm-350m",
                                  "jamba-v0.1-52b", "deepseek-v2-236b"])
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode must reproduce the full forward's logits."""
    from repro.models import (decode_step, init_cache, init_params, prefill)
    import repro.models.transformer as tfm

    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = tfm.forward_full(params, cfg, tokens)

    caches = init_cache(cfg, B, max_len=S)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    for t in range(S):
        logits_t, caches = step(params, caches, tokens[:, t:t + 1],
                                jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(logits_full[:, t]),
            rtol=2e-3, atol=2e-3)
