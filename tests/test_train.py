"""Fault-tolerance tests: checkpoint round-trip, bitwise resume after a
mid-run failure, straggler watchdog, schedules, grad accumulation."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import init_params, loss_fn
from repro.train import (CheckpointManager, LoopConfig, OptConfig,
                         StragglerWatchdog, SyntheticLMData, TrainConfig,
                         TrainLoop, lr_at, make_initial_state,
                         make_train_step)
from repro.train.loop import _TransientError


def _cfg():
    return smoke(get_config("qwen3-0.6b"))


def test_checkpoint_roundtrip(tmp_path):
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    state = {"params": params, "opt": {"step": jnp.int32(7)}}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(state, step=7, meta={"arch": cfg.name})
    restored, manifest = mgr.restore(jax.eval_shape(lambda: state))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.arange(4)}
    for s in [10, 20, 30, 40]:
        mgr.save(state, s)
    assert mgr.all_steps() == [30, 40]


def test_checkpoint_milestones_kept(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, milestone_every=100)
    state = {"x": jnp.arange(4)}
    for s in [100, 150, 200, 250]:
        mgr.save(state, s)
    assert 100 in mgr.all_steps() and 200 in mgr.all_steps()
    assert 150 not in mgr.all_steps()


def test_data_determinism():
    cfg = _cfg()
    d = SyntheticLMData(cfg, batch=4, seq=16, seed=99)
    a = d.batch_at(12)
    b = d.batch_at(12)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = d.batch_at(13)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_resume_after_failure_is_bitwise(tmp_path):
    """Kill at step 7, restart, and the loss trajectory must match an
    uninterrupted run exactly."""
    cfg = _cfg()
    loop_cfg = LoopConfig(
        total_steps=10, ckpt_every=5, log_every=1, max_retries=0,
        train=TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0,
                                        total_steps=10)))
    data = SyntheticLMData(cfg, batch=2, seq=16, seed=5)

    def run(ckdir, injector=None):
        loop = TrainLoop(cfg, loop_cfg, data,
                         CheckpointManager(ckdir, keep=3),
                         make_initial_state(cfg, seed=0),
                         failure_injector=injector)
        return loop

    # uninterrupted reference
    ref = run(str(tmp_path / "a"))
    out_ref = ref.run()
    ref_losses = {h["step"]: h["loss"] for h in ref.history}

    # failing run: dies at step 7 (after the step-5 checkpoint)
    boom = {"armed": True}

    def injector(step):
        if step == 7 and boom["armed"]:
            raise _TransientError("node lost")

    crashed = run(str(tmp_path / "b"), injector)
    with pytest.raises(_TransientError):
        crashed.run()
    # restart: resumes from step 7's emergency checkpoint
    boom["armed"] = False
    resumed = run(str(tmp_path / "b"), injector)
    out = resumed.run()
    assert out["step"] == 10
    res_losses = {h["step"]: h["loss"] for h in resumed.history}
    for step, loss in res_losses.items():
        assert ref_losses[step] == pytest.approx(loss, rel=1e-6), (
            step, loss, ref_losses[step])


def test_straggler_watchdog_flags_outliers():
    w = StragglerWatchdog(k=3.0, warmup=3, floor_s=0.0)
    events = []
    for i in range(50):
        e = w.update(i, 0.1 + 0.001 * (i % 3))
        if e:
            events.append(e)
    assert not events
    e = w.update(50, 1.5)  # 15x step time — a straggling pod
    assert e is not None and e.dt == 1.5
    # detector stats not poisoned by the outlier
    assert w.mean < 0.2


def test_wsd_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd",
                   wsd_decay_frac=0.2, min_lr_ratio=0.1)
    lr5 = float(lr_at(jnp.int32(5), oc))      # warmup
    lr50 = float(lr_at(jnp.int32(50), oc))    # stable
    lr90 = float(lr_at(jnp.int32(90), oc))    # decaying
    lr100 = float(lr_at(jnp.int32(100), oc))  # floor
    assert lr5 == pytest.approx(0.5)
    assert lr50 == pytest.approx(1.0)
    assert 0.1 < lr90 < 1.0
    assert lr100 == pytest.approx(0.1)


def test_grad_accum_matches_full_batch():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    data = SyntheticLMData(cfg, batch=8, seq=16, seed=3)
    batch = data.batch_at(0)

    from repro.train.step import _grad_microbatched
    loss_m, g_m, _ = _grad_microbatched(params, batch, cfg, n_micro=4)
    (loss_f, _), g_f = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert float(loss_m) == pytest.approx(float(loss_f), rel=1e-5)
    for a, b in zip(jax.tree.leaves(g_m), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_prefetcher_yields_in_order():
    from repro.train import Prefetcher
    cfg = _cfg()
    d = SyntheticLMData(cfg, batch=2, seq=8, seed=1)
    pf = Prefetcher(d, start_step=3)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [3, 4, 5, 6]
