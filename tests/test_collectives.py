"""Ring collective-matmul tests (subprocess, 8 forced host devices):
numerical equality with the gathered reference + the all-gather actually
vanishing from the compiled module."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=420)


def test_ring_matmuls_in_serve_style_step():
    """The ring / psum-scatter collective matmuls inside ONE jitted
    serve-style step (embed -> up-proj via ring all-gather matmul ->
    activation -> down-proj via psum-scatter matmul -> logits argmax) on a
    forced-8-device host mesh, asserting token-level parity with the
    dense single-device reference — the shape the sharded decode engine
    (serve/shard.py) drives them in."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_allgather_matmul,
                                                psum_scatter_matmul)

        mesh = make_mesh((2, 4), ("data", "model"))
        B, D, F, V = 8, 64, 128, 256
        k = jax.random.key(0)
        emb = jax.random.normal(jax.random.fold_in(k, 0), (V, D))
        w_up = jax.random.normal(jax.random.fold_in(k, 1), (D, F)) / D**0.5
        w_dn = jax.random.normal(jax.random.fold_in(k, 2), (F, D)) / F**0.5
        head = jax.random.normal(jax.random.fold_in(k, 3), (D, V)) / D**0.5
        toks = jax.random.randint(jax.random.fold_in(k, 4), (B,), 0, V)

        def step(tokens, emb, w_up, w_dn, head):
            # one serve-style decode step over the packed batch: the
            # activation rows ride the collective-matmul pair the way the
            # sharded engine's FFN does (gather-in, scatter-out)
            x = emb[tokens]                                   # (B, D)
            h = ring_allgather_matmul(x, w_up, mesh)          # (B, F)
            h = jax.nn.silu(h)
            y = psum_scatter_matmul(h, w_dn, mesh)            # (B, D)
            logits = y @ head
            return jnp.argmax(logits, axis=-1)

        def ref(tokens):
            x = emb[tokens]
            y = jax.nn.silu(x @ w_up) @ w_dn
            return jnp.argmax(y @ head, axis=-1)

        # place operands the way the collective matmuls expect them
        got = jax.jit(step)(toks, emb,
                            jax.device_put(w_up, NamedSharding(
                                mesh, P(None, "model"))),
                            jax.device_put(w_dn, NamedSharding(
                                mesh, P("model", None))),
                            head)
        want = ref(toks)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        txt = jax.jit(step).lower(toks, emb, w_up, w_dn, head
                                  ).compile().as_text()
        assert "collective-permute" in txt and "reduce-scatter" in txt
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])


def test_ring_matmul_reduce_matches_psum():
    """ring_matmul_reduce inside a shard_map body vs the blocking
    ``row_parallel_psum(h @ w, axis)`` it replaces — same operands, same
    call site, N dividing AND not dividing the shard count — plus the
    compiled HLO trading its all-reduce for collective-permutes (the
    overlappable form the decode epilogues switch to at overlap="ring")."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_matmul_reduce,
                                                row_parallel_matmul,
                                                row_parallel_psum)

        n = 4
        mesh = make_mesh((1, n), ("data", "model"))
        for N in (128, 130, 7):      # dividing, +2 pad, N < shards
            B, K = 3, 64
            h = jax.random.normal(jax.random.key(N), (B, 2, K))
            w = jax.random.normal(jax.random.key(N + 1), (K, N)) / K**0.5

            def blocking(h, w):
                return row_parallel_psum(h @ w, "model")

            def ring(h, w):
                return row_parallel_matmul(h, w, "model", "ring")

            specs = dict(mesh=mesh, in_specs=(P(None, None, "model"),
                                              P("model", None)),
                         out_specs=P(), check_rep=False)
            want = jax.jit(shard_map(blocking, **specs))(h, w)
            got = jax.jit(shard_map(ring, **specs))(h, w)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-5, atol=2e-4)
            txt = jax.jit(shard_map(ring, **specs)).lower(h, w
                                                          ).compile().as_text()
            assert "collective-permute" in txt, N
            assert "all-reduce" not in txt, N
        # dispatcher: axis=None is the plain matmul; bad mode raises
        h2 = jax.random.normal(jax.random.key(9), (3, 2, 64))
        w2 = jax.random.normal(jax.random.key(10), (64, 16))
        np.testing.assert_array_equal(
            np.asarray(row_parallel_matmul(h2, w2, None, "ring")),
            np.asarray(h2 @ w2))
        try:
            row_parallel_matmul(h2, w2, None, "eager")
        except ValueError:
            pass
        else:
            raise AssertionError("bad overlap mode accepted")
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])


def test_ring_matmuls_pad_non_dividing_shapes():
    """Pad-and-slice: the standalone ring matmuls accept S / N that do
    not divide the shard count (zero rows/columns padded inside the
    jitted body, sliced back after) and still match the dense product."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_allgather_matmul,
                                                psum_scatter_matmul)

        mesh = make_mesh((2, 4), ("data", "model"))
        for S, K, N in ((30, 64, 130), (5, 32, 3), (32, 64, 129)):
            x = jax.random.normal(jax.random.key(S), (S, K))
            w = jax.random.normal(jax.random.key(N), (K, N)) / K**0.5
            got = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh)
                          )(x, w)
            np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                       rtol=2e-5, atol=2e-4)
            got2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh)
                           )(x, w)
            np.testing.assert_allclose(np.asarray(got2), np.asarray(x @ w),
                                       rtol=2e-5, atol=2e-4)
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])


def test_sharded_engine_overlap_ring_byte_identical():
    """Acceptance seam of the overlap PR: greedy decode tokens from the
    tensor-parallel engine with overlap="ring" AND pipeline="double" are
    byte-identical to the single-device serial engine — GQA (qwen3) and
    a dense-FFN MLA config (MoE blocks need expert parallelism, a
    different seam), tp=2 on a forced-8-device mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config, smoke
        from repro.models import init_params
        from repro.models.common import BlockDef
        from repro.serve import (EngineConfig, GenerateConfig, make_engine,
                                 tp_sharding_error)

        def tokens(cfg, params, mesh, pipeline, overlap):
            eng = make_engine(cfg, params, EngineConfig(
                num_slots=2, page_size=4, max_len=32,
                pipeline=pipeline, overlap=overlap), mesh_shape=mesh)
            gen = GenerateConfig(max_new_tokens=6)
            prompts = [np.asarray(jax.random.randint(
                jax.random.key(50 + i), (5 + i,), 0, cfg.vocab_size),
                np.int32) for i in range(3)]
            reqs = [eng.submit(p, gen) for p in prompts]
            eng.run()
            return [list(r.generated) for r in reqs]

        gqa = smoke(get_config("qwen3-0.6b"))
        mla = dataclasses.replace(
            smoke(get_config("deepseek-v2-236b")), name="mla-dense-smoke",
            n_experts=0, moe_top_k=0, moe_d_ff=0, n_shared_experts=0,
            moe_first_dense=0, n_layers=2,
            block_pattern=(BlockDef("mla", "dense"),))
        for cfg in (gqa, mla):
            assert tp_sharding_error(cfg, 2) is None, cfg.name
            params = init_params(cfg, jax.random.key(0))
            base = tokens(cfg, params, (1, 1), "off", "none")
            got = tokens(cfg, params, (1, 2), "double", "ring")
            assert got == base, (cfg.name, got, base)
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])


def test_ring_matmuls_match_reference():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_allgather_matmul,
                                                psum_scatter_matmul)

        mesh = make_mesh((2, 4), ("data", "model"))
        S, K, N = 32, 64, 128
        x = jax.random.normal(jax.random.key(0), (S, K))
        w = jax.random.normal(jax.random.key(1), (K, N))

        got = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-4)
        # the ring form contains ppermutes, not an all-gather of x
        txt = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh)
                      ).lower(x, w).compile().as_text()
        assert "collective-permute" in txt

        got2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-4)
        txt2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh)
                       ).lower(x, w).compile().as_text()
        assert "reduce-scatter" in txt2
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])
