"""Ring collective-matmul tests (subprocess, 8 forced host devices):
numerical equality with the gathered reference + the all-gather actually
vanishing from the compiled module."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=420)


def test_ring_matmuls_match_reference():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_allgather_matmul,
                                                psum_scatter_matmul)

        mesh = make_mesh((2, 4), ("data", "model"))
        S, K, N = 32, 64, 128
        x = jax.random.normal(jax.random.key(0), (S, K))
        w = jax.random.normal(jax.random.key(1), (K, N))

        got = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-4)
        # the ring form contains ppermutes, not an all-gather of x
        txt = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh)
                      ).lower(x, w).compile().as_text()
        assert "collective-permute" in txt

        got2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-4)
        txt2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh)
                       ).lower(x, w).compile().as_text()
        assert "reduce-scatter" in txt2
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])
