"""Ring collective-matmul tests (subprocess, 8 forced host devices):
numerical equality with the gathered reference + the all-gather actually
vanishing from the compiled module."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=420)


def test_ring_matmuls_in_serve_style_step():
    """The ring / psum-scatter collective matmuls inside ONE jitted
    serve-style step (embed -> up-proj via ring all-gather matmul ->
    activation -> down-proj via psum-scatter matmul -> logits argmax) on a
    forced-8-device host mesh, asserting token-level parity with the
    dense single-device reference — the shape the sharded decode engine
    (serve/shard.py) drives them in."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_allgather_matmul,
                                                psum_scatter_matmul)

        mesh = make_mesh((2, 4), ("data", "model"))
        B, D, F, V = 8, 64, 128, 256
        k = jax.random.key(0)
        emb = jax.random.normal(jax.random.fold_in(k, 0), (V, D))
        w_up = jax.random.normal(jax.random.fold_in(k, 1), (D, F)) / D**0.5
        w_dn = jax.random.normal(jax.random.fold_in(k, 2), (F, D)) / F**0.5
        head = jax.random.normal(jax.random.fold_in(k, 3), (D, V)) / D**0.5
        toks = jax.random.randint(jax.random.fold_in(k, 4), (B,), 0, V)

        def step(tokens, emb, w_up, w_dn, head):
            # one serve-style decode step over the packed batch: the
            # activation rows ride the collective-matmul pair the way the
            # sharded engine's FFN does (gather-in, scatter-out)
            x = emb[tokens]                                   # (B, D)
            h = ring_allgather_matmul(x, w_up, mesh)          # (B, F)
            h = jax.nn.silu(h)
            y = psum_scatter_matmul(h, w_dn, mesh)            # (B, D)
            logits = y @ head
            return jnp.argmax(logits, axis=-1)

        def ref(tokens):
            x = emb[tokens]
            y = jax.nn.silu(x @ w_up) @ w_dn
            return jnp.argmax(y @ head, axis=-1)

        # place operands the way the collective matmuls expect them
        got = jax.jit(step)(toks, emb,
                            jax.device_put(w_up, NamedSharding(
                                mesh, P(None, "model"))),
                            jax.device_put(w_dn, NamedSharding(
                                mesh, P("model", None))),
                            head)
        want = ref(toks)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        txt = jax.jit(step).lower(toks, emb, w_up, w_dn, head
                                  ).compile().as_text()
        assert "collective-permute" in txt and "reduce-scatter" in txt
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])


def test_ring_matmuls_match_reference():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.mesh import make_mesh
        from repro.parallel.collectives import (ring_allgather_matmul,
                                                psum_scatter_matmul)

        mesh = make_mesh((2, 4), ("data", "model"))
        S, K, N = 32, 64, 128
        x = jax.random.normal(jax.random.key(0), (S, K))
        w = jax.random.normal(jax.random.key(1), (K, N))

        got = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-4)
        # the ring form contains ppermutes, not an all-gather of x
        txt = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh)
                      ).lower(x, w).compile().as_text()
        assert "collective-permute" in txt

        got2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-4)
        txt2 = jax.jit(lambda a, b: psum_scatter_matmul(a, b, mesh)
                       ).lower(x, w).compile().as_text()
        assert "reduce-scatter" in txt2
        print("RESULT ok")
    """)
    r = run_py(code)
    assert "RESULT ok" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])
