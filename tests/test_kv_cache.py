"""Paged KV cache: write/read round-trips equal the dense cache, and the
block-pool view keeps its invariants (reserved trash page, reuse,
exhaustion, on-demand growth, prefix sharing + copy-on-write, swap)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import decode_step, decode_step_paged, init_cache, \
    init_params, prefill
from repro.serve import (PagedKVCache, supports_paging,
                         supports_prefix_cache)
from repro.serve.engine import _place_prefill_states


def _leaves(tree):
    return jax.tree.leaves(tree)


def _prefilled(arch, S=6, seed=0):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(seed))
    prompt = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    last_logits, states = prefill(params, cfg, prompt)
    return cfg, params, prompt, last_logits, states


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v2-236b",
                                  "xlstm-350m", "jamba-v0.1-52b"])
def test_prefill_roundtrip_matches_dense(arch):
    """Scattering collected prefill states into pages and gathering them
    back equals the dense cache for attention (k/v), MLA (latent), and
    recurrent (ssm/xlstm) layer states."""
    S = 6
    cfg, params, prompt, _, states = _prefilled(arch, S)
    max_len = 8
    dense = _place_prefill_states(cfg, init_cache(cfg, 1, max_len), states, S)

    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=max_len)
    slot = kv.alloc(max_len)
    kv.write_prefill_states(slot, states, S)
    view = kv.dense_view(slot)

    for seg_d, seg_v, seg_p in zip(dense, view, kv._paged):
        for d, v, paged in zip(_leaves(seg_d), _leaves(seg_v),
                               _leaves(seg_p)):
            assert v.shape == d.shape, (v.shape, d.shape)
            if paged:
                # only the S written positions are meaningful
                np.testing.assert_array_equal(np.asarray(v[:, :, :S]),
                                              np.asarray(d[:, :, :S]))
            else:
                np.testing.assert_array_equal(np.asarray(v), np.asarray(d))


def test_decode_write_roundtrip_matches_dense():
    """One paged decode step writes the new token's KV line into the right
    page/offset: gathered cache equals the dense decode_step cache."""
    S, max_len = 6, 8
    cfg, params, prompt, last_logits, states = _prefilled("qwen3-0.6b", S)
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

    dense = _place_prefill_states(cfg, init_cache(cfg, 1, max_len), states, S)
    logits_d, dense = decode_step(params, cfg, dense, tok[:, None],
                                  jnp.int32(S))

    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=max_len)
    slot = kv.alloc(max_len)
    kv.write_prefill_states(slot, states, S)
    ns = kv.num_slots
    token = np.zeros((ns, 1), np.int32)
    token[slot] = int(tok[0])
    pos = np.zeros((ns,), np.int32)
    pos[slot] = S
    active = np.zeros((ns,), bool)
    active[slot] = True
    logits_p, kv.pools = decode_step_paged(
        params, cfg, kv.pools, kv.block_tables_for([slot]),
        jnp.asarray(token), jnp.asarray(pos), jnp.asarray(active),
        page_size=kv.page_size)
    np.testing.assert_allclose(np.asarray(logits_p[slot]),
                               np.asarray(logits_d[0]), rtol=1e-5,
                               atol=1e-5)
    view = kv.dense_view(slot)
    for seg_v, seg_d in zip(view, dense):
        for v, d in zip(_leaves(seg_v), _leaves(seg_d)):
            if v.ndim >= 3 and v.shape[2] == max_len:        # seq-carrying
                np.testing.assert_allclose(np.asarray(v[:, :, : S + 1]),
                                           np.asarray(d[:, :, : S + 1]),
                                           rtol=1e-6, atol=1e-6)


def test_allocator_invariants():
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16)
    assert kv.blocks_per_slot == 4
    assert kv.num_pages == 1 + 2 * 4          # fully backed + trash page

    a = kv.alloc(16)
    b = kv.alloc(9)                            # 3 pages
    assert a is not None and b is not None and a != b
    assert 0 not in kv.block_tables[a], "physical page 0 is reserved"
    used = set(kv.block_tables[a]) | set(kv.block_tables[b][:3])
    assert len(used) == 7, "pages must not be shared between slots"
    assert kv.alloc(4) is None, "slots exhausted"
    assert not kv.can_admit(4)

    kv.free(a)
    assert np.all(kv.block_tables[a] == 0)
    assert kv.can_admit(16)
    c = kv.alloc(16)
    assert c == a, "freed slot is reused"
    with pytest.raises(ValueError):
        kv.alloc(17)                           # > max_len


def test_block_tables_for_masks_inactive_slots():
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=8)
    s0, s1 = kv.alloc(8), kv.alloc(8)
    bt = np.asarray(kv.block_tables_for([s0]))
    assert np.all(bt[s1] == 0), "non-listed slots point at the trash page"
    assert np.all(bt[s0] == kv.block_tables[s0])


def test_supports_paging_flags():
    assert supports_paging(smoke(get_config("qwen3-0.6b")))
    assert supports_paging(smoke(get_config("deepseek-v2-236b")))
    assert supports_paging(smoke(get_config("xlstm-350m")))
    assert not supports_paging(smoke(get_config("whisper-small")))
    assert not supports_paging(smoke(get_config("llama-3.2-vision-90b")))
    with pytest.raises(NotImplementedError):
        PagedKVCache(smoke(get_config("whisper-small")), 2, 4, 8)


def test_margin_tokens_widen_tables_without_backing():
    """Speculative verification margin: block tables grow past the
    admission ceiling, margin entries stay on the trash page, and neither
    max_len nor the backing-pool size moves."""
    cfg = smoke(get_config("qwen3-0.6b"))
    base = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16)
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16,
                      margin_tokens=5)
    assert kv.max_len == base.max_len == 16
    assert kv.num_pages == base.num_pages
    assert kv.blocks_per_slot == base.blocks_per_slot + 2   # ceil(5/4)
    s = kv.alloc(16)                       # full admission budget
    assert np.all(kv.block_tables[s][-2:] == 0), "margin entries are trash"
    assert kv.block_tables[s].shape[0] == kv.blocks_per_slot
    # dense_view still returns the admission-sized window
    view = kv.dense_view(s)
    leaf = jax.tree.leaves(view[0])[0]
    assert leaf.shape[2] == 16


def test_alloc_pins_requested_slot():
    """A draft-model cache mirrors the target engine's slot indices."""
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=8)
    assert kv.alloc(8, slot=1) == 1
    assert kv.alloc(8, slot=0) == 0
    with pytest.raises(ValueError):
        kv.alloc(8, slot=1)                # already taken
    kv.free(1)
    assert kv.alloc(8, slot=1) == 1


# -- block-pool refactor: on-demand growth, sharing, CoW, swap -------------

def test_on_demand_growth_and_budget_clip():
    """A slot backed only for its prompt grows one page at a time as the
    write frontier advances; past-budget positions clip to the trash
    margin and never consume pages."""
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16,
                      margin_tokens=3)
    s = kv.alloc(5, budget=14)             # 2 pages now, 4 at full budget
    assert kv.slot_pages(s) == 2
    free0 = kv.free_page_count
    assert np.all(kv.block_tables[s][2:] == 0)
    assert kv.ensure_writable(s, 5, 6)     # within page 2: no growth
    assert kv.slot_pages(s) == 2 and kv.free_page_count == free0
    assert kv.ensure_writable(s, 8, 9)     # crosses into block 2
    assert kv.slot_pages(s) == 3
    assert kv.block_tables[s][2] != 0
    # a verify-style span pushing past the budget allocates only the
    # blocks the budget covers (14 tokens -> 4 blocks), trash beyond
    assert kv.ensure_writable(s, 12, 17)
    assert kv.slot_pages(s) == 4
    assert kv.block_tables[s][4] == 0, "past-budget entries stay trash"
    kv.pool.check(kv.table_refs())


def test_free_guards_double_free():
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=8)
    s = kv.alloc(8)
    kv.free(s)
    with pytest.raises(ValueError, match="double free"):
        kv.free(s)
    kv.pool.check(kv.table_refs())


def test_prefix_sharing_aliases_pages():
    """Two slots admitted with the same prompt share its full pages: the
    second alloc takes references instead of pages, and the pool's
    refcounts agree with the block tables."""
    cfg = smoke(get_config("qwen3-0.6b"))
    assert supports_prefix_cache(cfg)
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=16,
                      prefix_cache=True)
    toks = np.arange(10, dtype=np.int32)    # 2 full pages + 2 tail tokens
    a = kv.alloc(10, budget=16, tokens=toks)
    free_after_a = kv.free_page_count
    b = kv.alloc(10, budget=16, tokens=toks)
    assert kv.prefix_cached_tokens(a) == 0
    assert kv.prefix_cached_tokens(b) == 8
    assert free_after_a - kv.free_page_count == 1, \
        "the aliasing slot only needs its own tail page"
    np.testing.assert_array_equal(kv.block_tables[a][:2],
                                  kv.block_tables[b][:2])
    assert kv.block_tables[a][2] != kv.block_tables[b][2]
    assert kv.pool.stats.dedup_hits == 2
    kv.pool.check(kv.table_refs())
    # freeing the owner keeps the shared pages alive for the alias
    kv.free(a)
    kv.pool.check(kv.table_refs())
    assert kv.pool.refcount(int(kv.block_tables[b][0])) == 1


def test_prefix_cache_rejects_unsupported_arch():
    cfg = smoke(get_config("xlstm-350m"))
    assert not supports_prefix_cache(cfg)
    with pytest.raises(NotImplementedError, match="prefix"):
        PagedKVCache(cfg, 2, 4, 8, prefix_cache=True)


def test_cow_isolates_divergent_writes():
    """A write into a shared page copies it first: the writer gets a
    private page with identical bytes, the sibling's view never moves."""
    S = 8                                    # page-aligned prompt
    cfg, params, prompt, _, states = _prefilled("qwen3-0.6b", S)
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16,
                      prefix_cache=True)
    toks = np.asarray(prompt[0])
    a = kv.alloc(S, budget=16, tokens=toks)
    kv.write_prefill_states(a, states, S)
    b = kv.alloc(S, budget=16, tokens=toks)
    assert kv.prefix_cached_tokens(b) == S - 1, \
        "aligned full match recomputes exactly the last token"
    np.testing.assert_array_equal(kv.block_tables[a][:2],
                                  kv.block_tables[b][:2])
    before_a = jax.tree.leaves(kv.dense_view(a)[0])[0].copy()
    # b's first write lands in the shared final page -> copy-on-write
    assert kv.ensure_writable(b, S - 1, S)
    assert kv.pool.stats.cow_copies == 1
    assert kv.block_tables[a][1] != kv.block_tables[b][1]
    after_a = jax.tree.leaves(kv.dense_view(a)[0])[0]
    np.testing.assert_array_equal(np.asarray(before_a), np.asarray(after_a))
    # the copy carried the original bytes
    va = jax.tree.leaves(kv.dense_view(a)[0])[0]
    vb = jax.tree.leaves(kv.dense_view(b)[0])[0]
    np.testing.assert_array_equal(np.asarray(va[:, :, :S]),
                                  np.asarray(vb[:, :, :S]))
    kv.pool.check(kv.table_refs())


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "xlstm-350m"])
def test_swap_roundtrip_restores_bytes(arch):
    """swap_out -> swap_in round-trips a slot's pages (and recurrent state
    rows for hybrid archs) through host memory byte-exactly, possibly
    into a different slot."""
    S = 6
    cfg, params, prompt, _, states = _prefilled(arch, S)
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=12)
    s = kv.alloc(S, budget=12)
    kv.write_prefill_states(s, states, S)
    kv.ensure_writable(s, S, S + 1)          # grow one decode page
    before = [np.asarray(x) for x in jax.tree.leaves(kv.dense_view(s))]
    n_pages = kv.slot_pages(s)
    free0 = kv.free_page_count
    snap = kv.swap_out(s)
    assert snap.nbytes > 0
    assert kv.free_page_count == free0 + n_pages
    # occupy the old slot so the restore must land elsewhere
    blocker = kv.alloc(4, slot=s)
    s2 = kv.swap_in(snap)
    assert s2 is not None and s2 != s
    after = [np.asarray(x) for x in jax.tree.leaves(kv.dense_view(s2))]
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, y)
    assert kv.slot_pages(s2) == n_pages
    kv.free(blocker)
    kv.free(s2)
    kv.pool.check(kv.table_refs())


def test_swap_in_rededuplicates_frozen_prefix():
    """Frozen prefix pages that survive in the index are re-aliased on
    swap-in instead of copied back: the resume consumes fewer fresh
    pages than it released."""
    S = 8
    cfg, params, prompt, _, states = _prefilled("qwen3-0.6b", S)
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16,
                      prefix_cache=True)
    toks = np.asarray(prompt[0])
    s = kv.alloc(S, budget=16, tokens=toks)
    kv.write_prefill_states(s, states, S)
    snap = kv.swap_out(s)
    assert snap.frozen_blocks == 2
    # both frozen pages still sit in the reuse cache -> zero fresh pages
    assert kv.swap_in_pages_needed(snap) == 0
    free0 = kv.free_page_count
    s2 = kv.swap_in(snap)
    assert s2 is not None
    assert kv.free_page_count == free0, "re-aliased, not re-acquired"
    kv.pool.check(kv.table_refs())
