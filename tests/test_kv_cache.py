"""Paged KV cache: write/read round-trips equal the dense cache, and the
slot/page allocator keeps its invariants (reserved trash page, reuse,
exhaustion)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import decode_step, decode_step_paged, init_cache, \
    init_params, prefill
from repro.serve import PagedKVCache, supports_paging
from repro.serve.engine import _place_prefill_states


def _leaves(tree):
    return jax.tree.leaves(tree)


def _prefilled(arch, S=6, seed=0):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(seed))
    prompt = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    last_logits, states = prefill(params, cfg, prompt)
    return cfg, params, prompt, last_logits, states


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v2-236b",
                                  "xlstm-350m", "jamba-v0.1-52b"])
def test_prefill_roundtrip_matches_dense(arch):
    """Scattering collected prefill states into pages and gathering them
    back equals the dense cache for attention (k/v), MLA (latent), and
    recurrent (ssm/xlstm) layer states."""
    S = 6
    cfg, params, prompt, _, states = _prefilled(arch, S)
    max_len = 8
    dense = _place_prefill_states(cfg, init_cache(cfg, 1, max_len), states, S)

    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=max_len)
    slot = kv.alloc(max_len)
    kv.write_prefill_states(slot, states, S)
    view = kv.dense_view(slot)

    for seg_d, seg_v, seg_p in zip(dense, view, kv._paged):
        for d, v, paged in zip(_leaves(seg_d), _leaves(seg_v),
                               _leaves(seg_p)):
            assert v.shape == d.shape, (v.shape, d.shape)
            if paged:
                # only the S written positions are meaningful
                np.testing.assert_array_equal(np.asarray(v[:, :, :S]),
                                              np.asarray(d[:, :, :S]))
            else:
                np.testing.assert_array_equal(np.asarray(v), np.asarray(d))


def test_decode_write_roundtrip_matches_dense():
    """One paged decode step writes the new token's KV line into the right
    page/offset: gathered cache equals the dense decode_step cache."""
    S, max_len = 6, 8
    cfg, params, prompt, last_logits, states = _prefilled("qwen3-0.6b", S)
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

    dense = _place_prefill_states(cfg, init_cache(cfg, 1, max_len), states, S)
    logits_d, dense = decode_step(params, cfg, dense, tok[:, None],
                                  jnp.int32(S))

    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=max_len)
    slot = kv.alloc(max_len)
    kv.write_prefill_states(slot, states, S)
    ns = kv.num_slots
    token = np.zeros((ns, 1), np.int32)
    token[slot] = int(tok[0])
    pos = np.zeros((ns,), np.int32)
    pos[slot] = S
    active = np.zeros((ns,), bool)
    active[slot] = True
    logits_p, kv.pools = decode_step_paged(
        params, cfg, kv.pools, kv.block_tables_for([slot]),
        jnp.asarray(token), jnp.asarray(pos), jnp.asarray(active),
        page_size=kv.page_size)
    np.testing.assert_allclose(np.asarray(logits_p[slot]),
                               np.asarray(logits_d[0]), rtol=1e-5,
                               atol=1e-5)
    view = kv.dense_view(slot)
    for seg_v, seg_d in zip(view, dense):
        for v, d in zip(_leaves(seg_v), _leaves(seg_d)):
            if v.ndim >= 3 and v.shape[2] == max_len:        # seq-carrying
                np.testing.assert_allclose(np.asarray(v[:, :, : S + 1]),
                                           np.asarray(d[:, :, : S + 1]),
                                           rtol=1e-6, atol=1e-6)


def test_allocator_invariants():
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16)
    assert kv.blocks_per_slot == 4
    assert kv.num_pages == 1 + 2 * 4          # fully backed + trash page

    a = kv.alloc(16)
    b = kv.alloc(9)                            # 3 pages
    assert a is not None and b is not None and a != b
    assert 0 not in kv.block_tables[a], "physical page 0 is reserved"
    used = set(kv.block_tables[a]) | set(kv.block_tables[b][:3])
    assert len(used) == 7, "pages must not be shared between slots"
    assert kv.alloc(4) is None, "slots exhausted"
    assert not kv.can_admit(4)

    kv.free(a)
    assert np.all(kv.block_tables[a] == 0)
    assert kv.can_admit(16)
    c = kv.alloc(16)
    assert c == a, "freed slot is reused"
    with pytest.raises(ValueError):
        kv.alloc(17)                           # > max_len


def test_block_tables_for_masks_inactive_slots():
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=8)
    s0, s1 = kv.alloc(8), kv.alloc(8)
    bt = np.asarray(kv.block_tables_for([s0]))
    assert np.all(bt[s1] == 0), "non-listed slots point at the trash page"
    assert np.all(bt[s0] == kv.block_tables[s0])


def test_supports_paging_flags():
    assert supports_paging(smoke(get_config("qwen3-0.6b")))
    assert supports_paging(smoke(get_config("deepseek-v2-236b")))
    assert supports_paging(smoke(get_config("xlstm-350m")))
    assert not supports_paging(smoke(get_config("whisper-small")))
    assert not supports_paging(smoke(get_config("llama-3.2-vision-90b")))
    with pytest.raises(NotImplementedError):
        PagedKVCache(smoke(get_config("whisper-small")), 2, 4, 8)


def test_margin_tokens_widen_tables_without_backing():
    """Speculative verification margin: block tables grow past the
    admission ceiling, margin entries stay on the trash page, and neither
    max_len nor the backing-pool size moves."""
    cfg = smoke(get_config("qwen3-0.6b"))
    base = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16)
    kv = PagedKVCache(cfg, num_slots=2, page_size=4, max_len=16,
                      margin_tokens=5)
    assert kv.max_len == base.max_len == 16
    assert kv.num_pages == base.num_pages
    assert kv.blocks_per_slot == base.blocks_per_slot + 2   # ceil(5/4)
    s = kv.alloc(16)                       # full admission budget
    assert np.all(kv.block_tables[s][-2:] == 0), "margin entries are trash"
    assert kv.block_tables[s].shape[0] == kv.blocks_per_slot
    # dense_view still returns the admission-sized window
    view = kv.dense_view(s)
    leaf = jax.tree.leaves(view[0])[0]
    assert leaf.shape[2] == 16


def test_alloc_pins_requested_slot():
    """A draft-model cache mirrors the target engine's slot indices."""
    cfg = smoke(get_config("qwen3-0.6b"))
    kv = PagedKVCache(cfg, num_slots=3, page_size=4, max_len=8)
    assert kv.alloc(8, slot=1) == 1
    assert kv.alloc(8, slot=0) == 0
    with pytest.raises(ValueError):
        kv.alloc(8, slot=1)                # already taken
    kv.free(1)
    assert kv.alloc(8, slot=1) == 1
