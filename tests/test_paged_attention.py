"""Paged-attention decode: Pallas kernel parity (interpret mode) vs the
jnp gather reference, across GQA/MLA shapes, ragged page counts, and idle
trash-page lanes — plus end-to-end engine byte-identity between the
pallas-dispatch path and the jnp reference path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.kernels import ops
from repro.kernels import paged_attention as pa
from repro.models import init_params
from repro.serve import Engine, EngineConfig, GenerateConfig


def _ragged_tables(rng, B, n_blocks, page, num_pages):
    """Random ragged block tables: slot b owns 1..n_blocks live pages;
    dead entries stay 0 (the trash page)."""
    bt = np.zeros((B, n_blocks), np.int32)
    pos = np.zeros((B,), np.int32)
    free = list(range(1, num_pages))
    for b in range(B):
        live = rng.randint(1, n_blocks + 1)
        for j in range(live):
            bt[b, j] = free.pop()
        pos[b] = rng.randint(0, live * page)
    return jnp.asarray(bt), jnp.asarray(pos)


@pytest.mark.parametrize("B,KV,G,hd,page,nb", [
    (3, 2, 2, 16, 4, 5),      # GQA, odd block count
    (2, 4, 1, 32, 8, 3),      # MHA (G=1)
    (4, 1, 8, 64, 16, 2),     # MQA-style single KV head
])
def test_gqa_kernel_matches_reference(B, KV, G, hd, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 7 + nb), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt, pos = _ragged_tables(np.random.RandomState(B), B, nb, page, P)
    scale = hd ** -0.5
    ref = pa.paged_attention_reference(q, kp, vp, bt, pos, scale=scale)
    out = pa.paged_attention(q, kp, vp, bt, pos, scale=scale,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_gqa_kernel_soft_cap():
    B, KV, G, hd, page, nb = 2, 2, 2, 16, 4, 3
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd)) * 4.0
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt, pos = _ragged_tables(np.random.RandomState(3), B, nb, page, P)
    ref = pa.paged_attention_reference(q, kp, vp, bt, pos, scale=0.25,
                                       soft_cap=30.0)
    out = pa.paged_attention(q, kp, vp, bt, pos, scale=0.25, soft_cap=30.0,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_gqa_kernel_idle_trash_lane_is_finite():
    """An idle lane (pos=0, all-trash block table) must produce finite
    garbage, exactly like the reference — the engine discards it."""
    B, KV, G, hd, page, nb = 2, 2, 2, 16, 4, 3
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt = jnp.zeros((B, nb), jnp.int32)        # every lane idle -> trash page
    pos = jnp.zeros((B,), jnp.int32)
    out = pa.paged_attention(q, kp, vp, bt, pos, scale=hd ** -0.5,
                             interpret=True)
    ref = pa.paged_attention_reference(q, kp, vp, bt, pos, scale=hd ** -0.5)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,H,r,dr,page,nb", [
    (3, 4, 32, 8, 4, 4),
    (2, 8, 64, 16, 8, 2),
])
def test_mla_kernel_matches_reference(B, H, r, dr, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 13 + nb), 4)
    ql = jax.random.normal(ks[0], (B, H, r))
    qr = jax.random.normal(ks[1], (B, H, dr))
    cp = jax.random.normal(ks[2], (P, page, r))
    rp = jax.random.normal(ks[3], (P, page, dr))
    bt, pos = _ragged_tables(np.random.RandomState(B + 1), B, nb, page, P)
    scale = (r + dr) ** -0.5
    ref = pa.mla_paged_attention_reference(ql, qr, cp, rp, bt, pos,
                                           scale=scale)
    out = pa.mla_paged_attention(ql, qr, cp, rp, bt, pos, scale=scale,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,T,KV,G,hd,page,nb", [
    (3, 4, 2, 2, 16, 4, 5),   # GQA, odd block count
    (2, 2, 4, 1, 32, 8, 3),   # MHA (G=1)
    (2, 5, 1, 8, 64, 16, 2),  # MQA-style single KV head
])
def test_gqa_verify_kernel_matches_reference(B, T, KV, G, hd, page, nb):
    """Multi-token verification kernel vs the gather reference, ragged
    contexts: all T query rows share one page walk, per-row causal mask
    ``k_pos <= pos + t``."""
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 17 + T), 3)
    q = jax.random.normal(ks[0], (B, T, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt, pos = _ragged_tables(np.random.RandomState(B + T), B, nb, page, P)
    scale = hd ** -0.5
    ref = pa.paged_attention_verify_reference(q, kp, vp, bt, pos,
                                              scale=scale, soft_cap=20.0)
    out = pa.paged_attention_verify(q, kp, vp, bt, pos, scale=scale,
                                    soft_cap=20.0, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,T,H,r,dr,page,nb", [
    (3, 3, 4, 32, 8, 4, 4),
    (2, 5, 8, 64, 16, 8, 2),
])
def test_mla_verify_kernel_matches_reference(B, T, H, r, dr, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 19 + T), 4)
    ql = jax.random.normal(ks[0], (B, T, H, r))
    qr = jax.random.normal(ks[1], (B, T, H, dr))
    cp = jax.random.normal(ks[2], (P, page, r))
    rp = jax.random.normal(ks[3], (P, page, dr))
    bt, pos = _ragged_tables(np.random.RandomState(B + T + 1), B, nb, page,
                             P)
    scale = (r + dr) ** -0.5
    ref = pa.mla_paged_attention_verify_reference(ql, qr, cp, rp, bt, pos,
                                                  scale=scale)
    out = pa.mla_paged_attention_verify(ql, qr, cp, rp, bt, pos,
                                        scale=scale, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_verify_t1_matches_decode_reference():
    """A 1-token verification IS a decode step: both references must agree
    exactly (the contract that lets T=1 reasoning carry over)."""
    B, KV, G, hd, page, nb = 2, 2, 2, 16, 4, 3
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(23), 3)
    q = jax.random.normal(ks[0], (B, 1, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt, pos = _ragged_tables(np.random.RandomState(7), B, nb, page, P)
    dec = pa.paged_attention_reference(q[:, 0], kp, vp, bt, pos,
                                       scale=hd ** -0.5)
    ver = pa.paged_attention_verify_reference(q, kp, vp, bt, pos,
                                              scale=hd ** -0.5)[:, 0]
    np.testing.assert_allclose(np.asarray(ver), np.asarray(dec),
                               rtol=1e-6, atol=1e-7)


# -- double-buffered page streaming (pipeline="double") --------------------
# The manual-DMA kernels prefetch page b+1 into a second VMEM slab while
# computing page b; the schedule changes, the per-block f32 op sequence
# does not — so parity with the single-buffered kernel is BITWISE, not
# approximate, across ragged page counts and idle trash lanes.

@pytest.mark.parametrize("B,KV,G,hd,page,nb", [
    (3, 2, 2, 16, 4, 5),      # GQA, odd block count
    (2, 4, 1, 32, 8, 3),      # MHA (G=1)
    (4, 1, 8, 64, 16, 2),     # MQA-style single KV head
])
def test_gqa_pipeline_double_bitwise(B, KV, G, hd, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 7 + nb), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt, pos = _ragged_tables(np.random.RandomState(B), B, nb, page, P)
    kw = dict(scale=hd ** -0.5, soft_cap=25.0, interpret=True)
    off = pa.paged_attention(q, kp, vp, bt, pos, **kw)
    dbl = pa.paged_attention(q, kp, vp, bt, pos, pipeline="double", **kw)
    np.testing.assert_array_equal(np.asarray(dbl), np.asarray(off))
    ref = pa.paged_attention_reference(q, kp, vp, bt, pos, scale=hd ** -0.5,
                                       soft_cap=25.0)
    np.testing.assert_allclose(np.asarray(dbl), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,H,r,dr,page,nb", [
    (3, 4, 32, 8, 4, 4),
    (2, 8, 64, 16, 8, 2),
])
def test_mla_pipeline_double_bitwise(B, H, r, dr, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 13 + nb), 4)
    ql = jax.random.normal(ks[0], (B, H, r))
    qr = jax.random.normal(ks[1], (B, H, dr))
    cp = jax.random.normal(ks[2], (P, page, r))
    rp = jax.random.normal(ks[3], (P, page, dr))
    bt, pos = _ragged_tables(np.random.RandomState(B + 1), B, nb, page, P)
    kw = dict(scale=(r + dr) ** -0.5, interpret=True)
    off = pa.mla_paged_attention(ql, qr, cp, rp, bt, pos, **kw)
    dbl = pa.mla_paged_attention(ql, qr, cp, rp, bt, pos,
                                 pipeline="double", **kw)
    np.testing.assert_array_equal(np.asarray(dbl), np.asarray(off))
    ref = pa.mla_paged_attention_reference(ql, qr, cp, rp, bt, pos,
                                           scale=(r + dr) ** -0.5)
    np.testing.assert_allclose(np.asarray(dbl), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,T,KV,G,hd,page,nb", [
    (3, 4, 2, 2, 16, 4, 5),
    (2, 5, 1, 8, 64, 16, 2),
])
def test_gqa_verify_pipeline_double_bitwise(B, T, KV, G, hd, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 17 + T), 3)
    q = jax.random.normal(ks[0], (B, T, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt, pos = _ragged_tables(np.random.RandomState(B + T), B, nb, page, P)
    kw = dict(scale=hd ** -0.5, soft_cap=20.0, interpret=True)
    off = pa.paged_attention_verify(q, kp, vp, bt, pos, **kw)
    dbl = pa.paged_attention_verify(q, kp, vp, bt, pos, pipeline="double",
                                    **kw)
    np.testing.assert_array_equal(np.asarray(dbl), np.asarray(off))
    ref = pa.paged_attention_verify_reference(q, kp, vp, bt, pos,
                                              scale=hd ** -0.5,
                                              soft_cap=20.0)
    np.testing.assert_allclose(np.asarray(dbl), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,T,H,r,dr,page,nb", [
    (3, 3, 4, 32, 8, 4, 4),
    (2, 5, 8, 64, 16, 8, 2),
])
def test_mla_verify_pipeline_double_bitwise(B, T, H, r, dr, page, nb):
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(B * 19 + T), 4)
    ql = jax.random.normal(ks[0], (B, T, H, r))
    qr = jax.random.normal(ks[1], (B, T, H, dr))
    cp = jax.random.normal(ks[2], (P, page, r))
    rp = jax.random.normal(ks[3], (P, page, dr))
    bt, pos = _ragged_tables(np.random.RandomState(B + T + 1), B, nb, page,
                             P)
    kw = dict(scale=(r + dr) ** -0.5, interpret=True)
    off = pa.mla_paged_attention_verify(ql, qr, cp, rp, bt, pos, **kw)
    dbl = pa.mla_paged_attention_verify(ql, qr, cp, rp, bt, pos,
                                        pipeline="double", **kw)
    np.testing.assert_array_equal(np.asarray(dbl), np.asarray(off))
    ref = pa.mla_paged_attention_verify_reference(ql, qr, cp, rp, bt, pos,
                                                  scale=(r + dr) ** -0.5)
    np.testing.assert_allclose(np.asarray(dbl), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_pipeline_double_idle_trash_lane_is_finite():
    """The double-buffered kernel prefetches through all-trash block
    tables too (every DMA source is the trash page); idle lanes must
    stay finite and bitwise-match the single-buffered kernel."""
    B, KV, G, hd, page, nb = 2, 2, 2, 16, 4, 3
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt = jnp.zeros((B, nb), jnp.int32)        # every lane idle -> trash page
    pos = jnp.zeros((B,), jnp.int32)
    off = pa.paged_attention(q, kp, vp, bt, pos, scale=hd ** -0.5,
                             interpret=True)
    dbl = pa.paged_attention(q, kp, vp, bt, pos, scale=hd ** -0.5,
                             interpret=True, pipeline="double")
    assert np.isfinite(np.asarray(dbl)).all()
    np.testing.assert_array_equal(np.asarray(dbl), np.asarray(off))


def test_pipeline_rejects_unknown_mode():
    B, KV, G, hd, page, nb = 2, 2, 2, 16, 4, 3
    P = 1 + B * nb
    q = jnp.zeros((B, KV, G, hd))
    kp = jnp.zeros((P, page, KV, hd))
    vp = jnp.zeros((P, page, KV, hd))
    bt = jnp.zeros((B, nb), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    with pytest.raises(ValueError):
        pa.paged_attention(q, kp, vp, bt, pos, scale=1.0, interpret=True,
                           pipeline="triple")


def test_registry_resolves_backends():
    impls = ops.registered_kernels()
    assert {"paged_attention", "mla_paged_attention",
            "paged_attention_verify", "mla_paged_attention_verify",
            "flash_attention"} <= set(impls)
    assert ops.resolve("paged_attention", "jnp") \
        is pa.paged_attention_reference
    # pallas resolution binds interpret for this (CPU) process
    fn = ops.resolve("paged_attention", "pallas")
    assert fn.func is pa.paged_attention
    assert fn.keywords["interpret"] == (jax.default_backend() != "tpu")
    with ops.use_backend("jnp"):
        assert ops.resolve("mla_paged_attention") \
            is pa.mla_paged_attention_reference
    assert ops.default_backend() == "auto"
    with pytest.raises(ValueError):
        ops.resolve("paged_attention", "mosaic")


def test_registry_resolves_pipeline():
    """pipeline="double" binds into the pallas partial of pipelined ops
    only; the jnp reference has no pages to stream, and non-paged ops
    reject the request outright."""
    fn = ops.resolve("paged_attention", "pallas", pipeline="double")
    assert fn.func is pa.paged_attention
    assert fn.keywords["pipeline"] == "double"
    assert ops.resolve("paged_attention", "pallas").keywords["pipeline"] \
        == "off"
    # the reference path ignores the schedule — there is nothing to stream
    assert ops.resolve("paged_attention", "jnp", pipeline="double") \
        is pa.paged_attention_reference
    # flash_attention is not a paged streaming kernel
    with pytest.raises(ValueError):
        ops.resolve("flash_attention", "pallas", pipeline="double")
    with pytest.raises(ValueError):
        ops.resolve("paged_attention", "pallas", pipeline="triple")
    assert ops.default_pipeline() == "off"
    with ops.use_pipeline("double"):
        assert ops.resolve("mla_paged_attention", "pallas") \
            .keywords["pipeline"] == "double"
    assert ops.default_pipeline() == "off"


# -- end-to-end: engine tokens, pallas dispatch vs jnp reference ------------

def _engine_tokens(cfg, params, backend, arch_seed, pipeline="off"):
    eng = Engine(cfg, params, EngineConfig(
        num_slots=2, page_size=4, max_len=32, kernel_backend=backend,
        pipeline=pipeline))
    gen = GenerateConfig(max_new_tokens=6)
    prompts = [np.asarray(jax.random.randint(
        jax.random.key(arch_seed + i), (5 + i,), 0, cfg.vocab_size))
        for i in range(3)]
    reqs = [eng.submit(p, gen) for p in prompts]
    eng.run()
    return [list(r.generated) for r in reqs]


@pytest.mark.parametrize("arch,seed", [("qwen3-0.6b", 100),
                                       ("deepseek-v2-236b", 200)])
def test_engine_pallas_dispatch_byte_identical(arch, seed):
    """Continuous-engine output with the Pallas kernels (interpret mode)
    is byte-identical to the jnp reference path — dense GQA and MLA."""
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    tok_jnp = _engine_tokens(cfg, params, "jnp", seed)
    tok_pallas = _engine_tokens(cfg, params, "pallas", seed)
    assert tok_jnp == tok_pallas


@pytest.mark.parametrize("arch,seed", [("qwen3-0.6b", 100),
                                       ("deepseek-v2-236b", 200)])
def test_engine_pipeline_double_byte_identical(arch, seed):
    """End-to-end: the engine with the double-buffered page walk emits
    byte-identical greedy tokens to the single-buffered pallas path AND
    the jnp reference — GQA decode and MLA latent decode."""
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    tok_jnp = _engine_tokens(cfg, params, "jnp", seed)
    tok_dbl = _engine_tokens(cfg, params, "pallas", seed,
                             pipeline="double")
    assert tok_jnp == tok_dbl


def test_engine_pallas_dispatch_mla_absorb_equivalent():
    """mla_absorb only changes compute order; the paged path runs latent
    -space attention either way and tokens must agree."""
    cfg = smoke(get_config("deepseek-v2-236b"))
    params = init_params(cfg, jax.random.key(0))
    base = _engine_tokens(cfg, params, "jnp", 300)
    absorbed = _engine_tokens(
        dataclasses.replace(cfg, mla_absorb=True), params, "pallas", 300)
    assert base == absorbed


def test_mla_continuous_matches_static_byte_for_byte():
    """MLA continuous-vs-static byte identity (the contract the attn/xlstm
    tests pin for their cache families).  MoE-free MLA config so expert
    -capacity discontinuities can't confound; mla_absorb=True so the
    static dense decode runs the same latent form the paged path always
    uses."""
    from repro.models.common import BlockDef
    from repro.serve import StaticEngine
    cfg = smoke(get_config("deepseek-v2-236b"))
    cfg = dataclasses.replace(
        cfg, name="mla-dense-smoke", mla_absorb=True, n_experts=0,
        moe_top_k=0, moe_d_ff=0, n_shared_experts=0, moe_first_dense=0,
        n_layers=2, block_pattern=(BlockDef("mla", "dense"),))
    params = init_params(cfg, jax.random.key(0))
    gen = GenerateConfig(max_new_tokens=6)
    prompts = [np.asarray(jax.random.randint(
        jax.random.key(400 + i), (5 + i,), 0, cfg.vocab_size))
        for i in range(3)]
    eng = Engine(cfg, params, EngineConfig(num_slots=2, page_size=4,
                                           max_len=32))
    reqs = [eng.submit(p, gen) for p in prompts]
    eng.run()
    static = StaticEngine(cfg, params)
    for p, r in zip(prompts, reqs):
        ref = static.generate(jnp.asarray(p[None]), gen)
        np.testing.assert_array_equal(
            np.asarray(r.generated),
            np.asarray(ref["tokens"])[0, len(p):])
