"""End-to-end behaviour tests: every assigned architecture builds, trains a
step, and decodes on CPU (reduced configs of the same family structure)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          param_count)
from repro.models.common import SHAPES, applicable_shapes


def make_batch(cfg, B=2, S=16, key=0):
    k = jax.random.key(key)
    tokens = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            jax.random.key(key + 1), (B, cfg.n_audio_frames, cfg.d_model),
            jnp.float32)
    if cfg.n_image_tokens:
        batch["img_embeds"] = jax.random.normal(
            jax.random.key(key + 2), (B, cfg.n_image_tokens, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(arch):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss)), arch
    # ln(vocab) ± slack for a fresh init
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grad_finite_nonzero(arch):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    g = jax.jit(jax.grad(lambda p, b: loss_fn(p, b, cfg)[0]))(params, batch)
    gn = float(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g))) ** 0.5
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_runs(arch):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    B = 2
    caches = init_cache(cfg, B, max_len=32)
    tok = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab_size)
    logits, new_caches = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, jnp.int32(3)))(
        params, caches, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache structure round-trips
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_shape_applicability(arch):
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    assert "train_4k" in shapes
    if cfg.subquadratic:
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes


def test_train_step_reduces_loss():
    from repro.train.step import TrainConfig, make_train_step
    from repro.train.optimizer import OptConfig, init_opt_state

    cfg = smoke(get_config("qwen3-0.6b"))
    params = init_params(cfg, jax.random.key(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(
        cfg, TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=0,
                                       total_steps=100))))
    batch = make_batch(cfg, B=4, S=16)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
