"""The paper's contribution as a feature: automatic roofline construction
for (a) the live host via microbenchmarks, (b) any jitted function, and
(c) an assigned architecture cell from the archived dry-run.

    PYTHONPATH=src python examples/roofline_analysis.py
"""

import glob
import gzip
import json

import jax
import jax.numpy as jnp

from repro.core.analysis import kernel_character
from repro.core.roofline import (ascii_roofline, run_microbench)
from repro.kernels import ref


def main():
    # (a) measure the host's roofline (paper §2.1-2.2)
    mb = run_microbench(cache_path="results/microbench.json", quick=True)
    print(f"host: pi={mb.peak_flops / 1e9:.1f} GFLOP/s, "
          f"beta={mb.peak_bw / 1e9:.1f} GB/s")

    # (b) place kernels on it (paper §3)
    pts = []
    x = jax.random.normal(jax.random.key(0), (512, 512))
    w = jax.random.normal(jax.random.key(1), (512, 512))
    for name, fn, args in [
        ("matmul", ref.inner_product, (x, w)),
        ("gelu", ref.gelu, (x,)),
        ("layernorm", ref.layernorm,
         (x, jnp.ones((512,)), jnp.zeros((512,)))),
    ]:
        c = kernel_character(fn, *args)
        import time
        jitted = jax.jit(fn)
        jax.block_until_ready(jitted(*args))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(jitted(*args))
        dt = (time.perf_counter() - t0) / 5
        pts.append((name, c["AI"], c["W_flops"] / dt))
    print(ascii_roofline(pts, peak_flops=mb.peak_flops, mem_bw=mb.peak_bw))

    # (c) read an archived dry-run cell (TPU-target analysis)
    cells = sorted(glob.glob("results/dryrun/qwen3-14b__train_4k__pod.json"))
    if cells:
        d = json.load(open(cells[0]))
        if d.get("status") == "ok":
            print(f"\nqwen3-14b/train_4k on a v5e pod: bound={d['bound']}, "
                  f"t_lower={d['t_lower_s']:.3f}s, "
                  f"roofline fraction={d['roofline_fraction'] * 100:.2f}%")


if __name__ == "__main__":
    main()
