"""Quickstart: build a model, characterize its training step on the
roofline (the paper's methodology as a library), train a few steps, decode.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.core.analysis import analyze_step
from repro.core.roofline.hardware import HOST_CPU_FALLBACK
from repro.models import decode_step, init_cache, init_params, loss_fn
from repro.models.common import model_flops
from repro.parallel.mesh import make_host_mesh
from repro.parallel.sharding import sharding_context
from repro.serve import Engine, GenerateConfig
from repro.train import OptConfig, TrainConfig, init_opt_state, make_train_step


def main():
    # 1. a reduced qwen3 (same family structure, CPU-scale)
    cfg = smoke(get_config("qwen3-0.6b"))
    params = init_params(cfg, jax.random.key(0))
    print(f"model: {cfg.name}, "
          f"{sum(x.size for x in jax.tree.leaves(params)) / 1e6:.2f}M params")

    # 2. roofline-characterize the train step BEFORE running it
    mesh = make_host_mesh(data=1, model=1)
    B, S = 4, 64
    state = {"params": params, "opt": init_opt_state(params)}
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.zeros((B, S), jnp.int32),
    }
    step = make_train_step(cfg, TrainConfig(opt=OptConfig(lr=1e-3)))
    with sharding_context(mesh):
        report, compiled = analyze_step(
            step, args=(jax.eval_shape(lambda: state),
                        jax.eval_shape(lambda: batch)),
            mesh=mesh, label="quickstart train step",
            chip=HOST_CPU_FALLBACK, dtype="float32",
            model_flops=model_flops(cfg, S, B, "train"))
    print(report.render())

    # 3. train a few steps on synthetic data
    from repro.train import SyntheticLMData
    data = SyntheticLMData(cfg, B, S)
    for i in range(5):
        state, metrics = compiled(state, data.batch_at(i))
        print(f"  step {i}: loss={float(metrics['loss']):.4f} "
              f"lr={float(metrics['lr']):.2e}")

    # 4. decode with the serving engine
    engine = Engine(cfg, state["params"])
    prompts = jnp.ones((2, 8), jnp.int32)
    out = engine.generate(prompts, GenerateConfig(max_new_tokens=8))
    print("decoded:", out["tokens"][0, 8:].tolist())


if __name__ == "__main__":
    main()
