"""End-to-end training driver: a ~100M-param LM for a few hundred steps
with checkpointing, resume, straggler watch and WSD/cosine schedules.

Default invocation is CI-sized; pass --full for the real ~100M x 300-step
run (hours on this CPU container; the config is exactly what a v5e pod
would run via launch/train.py):

    PYTHONPATH=src python examples/train_lm.py            # ~2 min CPU
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M params
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config, smoke
from repro.models.common import ModelConfig
from repro.train import (CheckpointManager, LoopConfig, OptConfig,
                         SyntheticLMData, TrainConfig, TrainLoop,
                         make_initial_state)


def hundred_m_config() -> ModelConfig:
    """~100M-param llama-like config (qwen3 family, scaled)."""
    base = get_config("qwen3-0.6b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=12, d_model=640, n_heads=10,
        n_kv_heads=2, head_dim=64, d_ff=1792, vocab_size=32768,
        dtype="float32", remat="none", max_seq_len=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.full:
        cfg = hundred_m_config()
        steps = args.steps or 300
        batch, seq = 8, 512
    else:
        cfg = smoke(get_config("qwen3-0.6b"))
        steps = args.steps or 40
        batch, seq = 4, 64

    loop_cfg = LoopConfig(
        total_steps=steps, ckpt_every=max(steps // 4, 10),
        log_every=max(steps // 20, 1),
        train=TrainConfig(opt=OptConfig(
            lr=6e-4, warmup_steps=max(steps // 10, 5), total_steps=steps)))
    data = SyntheticLMData(cfg, batch, seq)
    loop = TrainLoop(cfg, loop_cfg, data,
                     CheckpointManager(f"results/ckpt/{cfg.name}", keep=2),
                     make_initial_state(cfg))
    out = loop.run()
    print(f"finished at step {out['step']}")
    first, last = loop.history[0], loop.history[-1]
    print(f"loss: {first['loss']:.4f} (step {first['step']}) -> "
          f"{last['loss']:.4f} (step {last['step']})")
    assert last["loss"] < first["loss"], "training did not reduce loss!"


if __name__ == "__main__":
    main()
