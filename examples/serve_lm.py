"""Continuous-batching serving example on three architecture families
(dense GQA paged KV, MLA paged latent cache, recurrent slot state).

More requests than decode slots: completions free slots mid-flight and
queued requests are admitted into them.  Each request finishes with a
decode roofline ledger (I = W/Q per token, bound class).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.serve import Engine, EngineConfig, GenerateConfig


def run(arch: str, requests: int = 6, slots: int = 3, prompt_len: int = 16,
        new: int = 16):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    engine = Engine(cfg, params, EngineConfig(
        num_slots=slots, page_size=8, max_len=prompt_len + new))
    gen = GenerateConfig(max_new_tokens=new)
    for i in range(requests):
        prompt = np.asarray(jax.random.randint(
            jax.random.key(100 + i), (prompt_len,), 0, cfg.vocab_size))
        engine.submit(prompt, gen)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    n_new = sum(len(r.generated) for r in done)
    terms = done[0].ledger.terms(cfg)
    kind = ("latent" if cfg.use_mla
            else ("state" if cfg.subquadratic else "kv"))
    print(f"{arch:<22} cache={kind:<6} {requests} reqs/{slots} slots "
          f"{n_new / dt:7.1f} tok/s  AI={terms.arithmetic_intensity:5.2f} "
          f"{terms.bound_class()}  sample={done[0].generated[:8]}")


def main():
    for arch in ("qwen3-0.6b", "deepseek-v2-236b", "xlstm-350m"):
        run(arch)


if __name__ == "__main__":
    main()
