"""Batched serving example: prefill + KV-cache decode on three different
architecture families (dense GQA, MLA latent cache, recurrent state).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.serve import Engine, GenerateConfig


def run(arch: str, batch: int = 4, prompt_len: int = 16, new: int = 16):
    cfg = smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    engine = Engine(cfg, params)
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = engine.generate(prompts, GenerateConfig(max_new_tokens=new))
    dt = time.perf_counter() - t0
    print(f"{arch:<22} cache={'latent' if cfg.use_mla else ('state' if cfg.subquadratic else 'kv')}"
          f"  {batch * new / dt:7.1f} tok/s  sample={out['tokens'][0, prompt_len:prompt_len + 8].tolist()}")


def main():
    for arch in ("qwen3-0.6b", "deepseek-v2-236b", "xlstm-350m"):
        run(arch)


if __name__ == "__main__":
    main()
